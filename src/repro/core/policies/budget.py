"""Budget-capped routing: hard per-window cost cap with cheapest-feasible
fallback (new policy, written *only* against the RoutingPolicy registry).

Production routers run under spend governance: a tenant's traffic must not
exceed a dollar budget per accounting window no matter what the deadline
structure wants. This policy keeps a per-window spend ledger in its scan
state and routes in three tiers:

1. while the window has budget, behave like the SLO policy *restricted to
   pairs the remaining budget can still afford* (cheapest deadline-feasible
   affordable pair);
2. if no affordable pair is deadline-feasible, sacrifice latency: cheapest
   affordable pair;
3. if the ledger is exhausted (nothing affordable), hard-cap mode: the
   globally cheapest pair — the request is served (no admission drop in
   this model) but at minimum marginal spend.

The ledger is the policy's per-policy scan state ``[window_id, spent]``
(see ``RoutingPolicy.state_size``), threaded through the JAX evaluator's
scan carry, both DES oracles, and the runtime router identically. Spend is
billed at **list price from the shared float32 cost table** (not the
realized cache-discounted cost): the three implementations then accumulate
bit-identical float32 ledgers, so routing decisions — which compare
``cost <= remaining`` — can never diverge between the scan-traced and
discrete-event executions (the cache-discounted realized cost mixes f32/f64
arithmetic across oracles).

Genome: [B (window budget, $), γ (deadline headroom), κ (wait s/load)].
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import register_policy
from .base import GenomeSpec, PolicyInputs, RoutingPolicy

BUDGET_PARAM_NAMES = ("window_budget", "gamma", "kappa")
BUDGET_BOUNDS_LO = np.array([0.001, 0.3, 0.0], np.float32)
BUDGET_BOUNDS_HI = np.array([0.05, 1.1, 20.0], np.float32)
BUDGET_DEFAULTS = np.array([0.01, 0.9, 3.0], np.float32)

#: Accounting window length in trace seconds. The runtime router defaults
#: ``now`` to its request counter (a window is then WINDOW_S consecutive
#: requests); callers that re-fit this policy on recorded arrival
#: timestamps (``RequestRouter.record(..., now=)``) must pass the same
#: clock to ``route(now=)`` so the tuned budget B is applied on the time
#: base it was optimized for.
WINDOW_S = 30.0


class BudgetPolicy(RoutingPolicy):
    name = "budget"
    genome_spec = GenomeSpec(names=BUDGET_PARAM_NAMES, lo=BUDGET_BOUNDS_LO,
                             hi=BUDGET_BOUNDS_HI, defaults=BUDGET_DEFAULTS)
    requires = frozenset({"estimates", "deadlines"})
    state_size = 2                      # [window_id, spent_this_window]

    def init_state(self) -> np.ndarray:
        return np.array([-1.0, 0.0], np.float32)

    # -- shared window arithmetic (float32 in both twins) ---------------------
    @staticmethod
    def _window_spent_jnp(state, now):
        w = jnp.floor(now / jnp.float32(WINDOW_S))
        spent = jnp.where(w == state[0], state[1], 0.0)
        return w, spent

    @staticmethod
    def _window_spent_py(state, now):
        w = np.float32(np.floor(np.float32(now) / np.float32(WINDOW_S)))
        spent = state[1] if w == state[0] else np.float32(0.0)
        return w, np.float32(spent)

    # -- decisions ------------------------------------------------------------
    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        B, gamma, kappa = genome[0], genome[1], genome[2]
        _, spent = self._window_spent_jnp(state, inp.now)
        remaining = jnp.maximum(B - spent, 0.0)

        load = (inp.queue_len.astype(jnp.float32)
                / arrays.node_conc.astype(jnp.float32))
        est_ttft = inp.up + kappa * load[arrays.pair_node] + inp.prefill
        feas_dl = (est_ttft <= gamma * inp.ttft_deadline) & \
                  (inp.tpot <= jnp.minimum(gamma, 1.0) * inp.tpot_deadline)
        affordable = inp.cost <= remaining
        feas = feas_dl & affordable

        cheapest_feas = jnp.argmin(jnp.where(feas, inp.cost, jnp.inf))
        cheapest_afford = jnp.argmin(jnp.where(affordable, inp.cost, jnp.inf))
        cheapest = jnp.argmin(inp.cost)
        pair = jnp.where(jnp.any(feas), cheapest_feas,
                         jnp.where(jnp.any(affordable), cheapest_afford,
                                   cheapest))
        return pair.astype(jnp.int32)

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        g = np.asarray(genome, np.float32)
        B, gamma, kappa = np.float32(g[0]), np.float32(g[1]), np.float32(g[2])
        _, spent = self._window_spent_py(state, inp.now)
        remaining = np.maximum(B - spent, np.float32(0.0))

        up = np.asarray(inp.up, np.float32)
        prefill = np.asarray(inp.prefill, np.float32)
        tpot = np.asarray(inp.tpot, np.float32)
        cost = np.asarray(inp.cost, np.float32)
        node = np.asarray(arrays.pair_node)
        conc = np.asarray(arrays.node_conc)
        load = np.asarray(inp.queue_len).astype(np.float32) / \
            conc.astype(np.float32)
        est_ttft = up + kappa * load[node] + prefill
        feas_dl = (est_ttft <= gamma * np.float32(inp.ttft_deadline)) & \
                  (tpot <= np.minimum(gamma, np.float32(1.0))
                   * np.float32(inp.tpot_deadline))
        affordable = cost <= remaining
        feas = feas_dl & affordable
        if feas.any():
            return int(np.argmin(np.where(feas, cost, np.inf)))
        if affordable.any():
            return int(np.argmin(np.where(affordable, cost, np.inf)))
        return int(np.argmin(cost))

    # -- ledger updates -------------------------------------------------------
    def update_jnp(self, genome, state, inp: PolicyInputs, pair, cost):
        # bill at list price from the shared f32 table (see module docstring)
        w, spent = self._window_spent_jnp(state, inp.now)
        return jnp.stack([w, spent + inp.cost[pair]])

    def update_py(self, genome, state, inp: PolicyInputs, pair: int,
                  cost: float) -> np.ndarray:
        w, spent = self._window_spent_py(state, inp.now)
        billed = np.float32(np.asarray(inp.cost, np.float32)[pair])
        return np.array([w, spent + billed], np.float32)


register_policy(BudgetPolicy())
