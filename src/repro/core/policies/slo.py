"""SLO-aware decision policy (QoE extension of the paper's router).

Instead of difficulty thresholds it estimates each pair's TTFT (upload +
predicted queue wait + prefill) and TPOT against the request's phase
deadlines and picks the *cheapest feasible* pair — deadline-tight requests
therefore land on low-queue/cloud pairs while relaxed ones ride cheap edge
pairs. Its genome is

    [γ (deadline headroom, <1 = conservative), κ (est. wait s per unit load)]

searchable by the same NSGA-II via ``TraceEvaluator.make_fitness("slo")``.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from ...cluster.spec import ClusterArrays
from . import register_policy
from .base import GenomeSpec, PolicyInputs, RoutingPolicy

SLO_PARAM_NAMES = ("gamma", "kappa")

# γ in [0.3, 1.1] (fraction of the deadline budget the estimate may use),
# κ in [0, 20] s of predicted wait at full load.
SLO_BOUNDS_LO = np.array([0.3, 0.0], np.float32)
SLO_BOUNDS_HI = np.array([1.1, 20.0], np.float32)

# sensible hand defaults: 10% headroom, ~3 s wait at a saturated node
SLO_DEFAULTS = np.array([0.9, 3.0], np.float32)


def _slo_scores_np(genome, ttft_deadline, tpot_deadline, up, prefill, tpot,
                   queue_len, node, conc):
    """Shared float32 arithmetic for the numpy oracle (mirrors the jnp path
    op-for-op so argmin tie-breaking is identical)."""
    gamma = np.float32(genome[0])
    kappa = np.float32(genome[1])
    load = queue_len.astype(np.float32) / conc.astype(np.float32)
    est_wait = kappa * load[node]
    est_ttft = up + est_wait + prefill
    # γ headroom hedges the *uncertain* TTFT estimate; TPOT is a known
    # constant per pair, so γ > 1 must not admit guaranteed TPOT misses
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= np.minimum(gamma, np.float32(1.0)) * tpot_deadline)
    overshoot = np.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    return feasible, est_ttft, overshoot


def decide_pair_slo_jnp(genome: jnp.ndarray, *, ttft_deadline: jnp.ndarray,
                        tpot_deadline: jnp.ndarray, up: jnp.ndarray,
                        prefill: jnp.ndarray, tpot: jnp.ndarray,
                        cost: jnp.ndarray, queue_len: jnp.ndarray,
                        arrays: ClusterArrays) -> jnp.ndarray:
    """SLO-aware routing: cheapest pair whose estimated phase times fit the
    deadline budget scaled by γ; if no pair is feasible, minimize the worst
    normalized deadline overshoot (degrades gracefully toward fast pairs).

    ``up``/``prefill``/``cost`` are this request's (n_pairs,) rows of the
    precomputed tables; ``tpot`` is the per-pair decode time (n_pairs,);
    ``queue_len`` is the (n_nodes,) busy-slot view from the monitor.
    """
    gamma = genome[0]
    kappa = genome[1]
    load = queue_len.astype(jnp.float32) / arrays.node_conc.astype(jnp.float32)
    est_wait = kappa * load[arrays.pair_node]
    est_ttft = up + est_wait + prefill
    # γ headroom applies to the uncertain TTFT estimate only; the TPOT term
    # clamps γ at 1 so a searchable γ > 1 cannot admit certain TPOT misses
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= jnp.minimum(gamma, 1.0) * tpot_deadline)
    any_ok = jnp.any(feasible)
    cheapest = jnp.argmin(jnp.where(feasible, cost, jnp.inf))
    overshoot = jnp.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    least_bad = jnp.argmin(overshoot)
    return jnp.where(any_ok, cheapest, least_bad).astype(jnp.int32)


def decide_pair_slo_py(genome: Sequence[float], *, ttft_deadline: float,
                       tpot_deadline: float, up: np.ndarray,
                       prefill: np.ndarray, tpot: np.ndarray,
                       cost: np.ndarray, queue_len: Sequence[int],
                       arrays: ClusterArrays) -> int:
    """Reference numpy transcription of the SLO decision (test oracle)."""
    node = np.asarray(arrays.pair_node)
    conc = np.asarray(arrays.node_conc)
    feasible, est_ttft, overshoot = _slo_scores_np(
        np.asarray(genome, np.float32),
        np.float32(ttft_deadline), np.float32(tpot_deadline),
        np.asarray(up, np.float32), np.asarray(prefill, np.float32),
        np.asarray(tpot, np.float32), np.asarray(queue_len), node, conc)
    if feasible.any():
        return int(np.argmin(np.where(feasible, np.asarray(cost, np.float32),
                                      np.inf)))
    return int(np.argmin(overshoot))


class SLOPolicy(RoutingPolicy):
    """Registered wrapper over the SLO decision pair."""

    name = "slo"
    genome_spec = GenomeSpec(names=SLO_PARAM_NAMES, lo=SLO_BOUNDS_LO,
                             hi=SLO_BOUNDS_HI, defaults=SLO_DEFAULTS)
    requires = frozenset({"estimates", "deadlines"})

    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        return decide_pair_slo_jnp(genome, ttft_deadline=inp.ttft_deadline,
                                   tpot_deadline=inp.tpot_deadline, up=inp.up,
                                   prefill=inp.prefill, tpot=inp.tpot,
                                   cost=inp.cost, queue_len=inp.queue_len,
                                   arrays=arrays)

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        return decide_pair_slo_py(genome, ttft_deadline=float(inp.ttft_deadline),
                                  tpot_deadline=float(inp.tpot_deadline),
                                  up=inp.up, prefill=inp.prefill,
                                  tpot=inp.tpot, cost=inp.cost,
                                  queue_len=inp.queue_len, arrays=arrays)


register_policy(SLOPolicy())
