"""Pluggable RoutingPolicy registry.

One registry drives every consumer of routing decisions:

* ``core.fitness._run_trace`` / ``TraceEvaluator.make_fitness`` — the JAX
  evaluator resolves the policy by name (a jit-**static** argument, so each
  policy identity compiles exactly one trace executable);
* both DES oracles (``cluster.simulator.ClusterSimulator.run`` /
  ``run_event_heap`` with ``policy=``) — in-loop decisions through the same
  object, so the JAX/DES equivalence property covers new policies for free;
* the runtime router (``core.router.RequestRouter(mode=<name>)``) including
  its rolling-horizon ``maybe_reoptimize`` re-fit;
* NSGA-II genome configuration (``core.nsga2.NSGA2Config.from_policy``).

Adding a policy is **one file** in this package: subclass
:class:`~repro.core.policies.base.RoutingPolicy`, call
:func:`register_policy` at module bottom, and every consumer above picks it
up automatically — modules in this package are auto-imported (sorted name
order) on first import, so there is no central list to edit. See
docs/architecture.md ("Policy registry & extension guide") for the
contract details.
"""
from __future__ import annotations

import importlib
import pkgutil
from typing import Dict, Tuple

from .base import GenomeSpec, PolicyInputs, RoutingPolicy  # noqa: F401

_REGISTRY: Dict[str, RoutingPolicy] = {}


def register_policy(policy: RoutingPolicy) -> RoutingPolicy:
    """Register ``policy`` under ``policy.name``. Idempotent for the same
    object (module reloads); a *different* object under a taken name is an
    error — policy identity is a jit cache key and must stay unambiguous."""
    assert policy.name, "policy must set a non-empty name"
    prev = _REGISTRY.get(policy.name)
    if prev is not None and type(prev) is not type(policy):
        raise ValueError(f"policy name {policy.name!r} already registered "
                         f"by {type(prev).__name__}")
    _REGISTRY[policy.name] = policy
    return policy


def get_policy(name: str) -> RoutingPolicy:
    """Resolve a policy by registry name.

    Raises ``ValueError`` naming every registered policy on unknown input —
    the single error surface for ``make_fitness``, ``RequestRouter`` and the
    DES oracles."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown routing policy {name!r}; registered policies: "
            f"{', '.join(list_policies())}") from None


def list_policies() -> Tuple[str, ...]:
    """Sorted names of every registered policy."""
    return tuple(sorted(_REGISTRY))


def runtime_policies() -> Tuple[str, ...]:
    """Policies that can drive the runtime router (fixed-length genomes;
    excludes per-request encodings like "direct")."""
    return tuple(n for n in list_policies()
                 if not _REGISTRY[n].genome_spec.per_request)


# -- auto-discovery: a new policy is one module dropped into this package ----
for _info in sorted(pkgutil.iter_modules(__path__), key=lambda m: m.name):
    if _info.name != "base" and not _info.name.startswith("_"):
        importlib.import_module(f"{__name__}.{_info.name}")
del _info

__all__ = ["GenomeSpec", "PolicyInputs", "RoutingPolicy", "register_policy",
           "get_policy", "list_policies", "runtime_policies"]
