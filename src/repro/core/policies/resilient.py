"""Resilience-aware decision policy (chaos extension of the SLO router).

An SLO-style cheapest-feasible rule with a **brownout** term: as cluster
utilization climbs past a searchable threshold ``u_hi``, the effective cost
of non-edge pairs inflates by ``β·brownout``, biasing routing toward cheap
edge pairs exactly when the expensive tier is the scarce resource. Under a
fault regime (crashes/stragglers masked out via the standard dead-pair
sentinels in ``queue_len``/``up``) the surviving capacity is what saturates,
so the brownout bias is what keeps SLO attainment from collapsing. Genome

    [γ (deadline headroom), κ (est. wait s per unit load),
     β (brownout cost inflation), u_hi (utilization knee)]

searchable by the same NSGA-II via ``TraceEvaluator.make_fitness
("resilient")`` — including against a faulty evaluator (``faults=``), which
is how ``benchmarks/chaos.py`` tunes it.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from ...cluster.spec import ClusterArrays
from . import register_policy
from .base import GenomeSpec, PolicyInputs, RoutingPolicy

RESILIENT_PARAM_NAMES = ("gamma", "kappa", "beta", "u_hi")

# γ, κ as in the slo policy; β in [0, 4] (cost inflation of non-edge pairs
# at full brownout); u_hi in [0.3, 1.0] (utilization where brownout starts).
RESILIENT_BOUNDS_LO = np.array([0.3, 0.0, 0.0, 0.3], np.float32)
RESILIENT_BOUNDS_HI = np.array([1.1, 20.0, 4.0, 1.0], np.float32)

# hand defaults: slo's (0.9, 3.0) plus a mild brownout past 70% utilization
RESILIENT_DEFAULTS = np.array([0.9, 3.0, 1.0, 0.7], np.float32)

# queue lengths at/above this are the router's dead-node sentinel
# (core.fitness.DEAD_QUEUE) — excluded from the utilization estimate
_DEAD_QUEUE = np.float32(10**6)


def _resilient_scores_np(genome, ttft_deadline, tpot_deadline, up, prefill,
                         tpot, cost, queue_len, node, conc, is_edge):
    """Shared float32 arithmetic for the numpy oracle (mirrors the jnp path
    op-for-op so argmin tie-breaking is identical)."""
    gamma = np.float32(genome[0])
    kappa = np.float32(genome[1])
    beta = np.float32(genome[2])
    u_hi = np.float32(genome[3])
    q = queue_len.astype(np.float32)
    alive = q < _DEAD_QUEUE
    load = q / conc.astype(np.float32)
    est_wait = kappa * load[node]
    est_ttft = up + est_wait + prefill
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= np.minimum(gamma, np.float32(1.0)) * tpot_deadline)
    # brownout: mean utilization of the *alive* nodes, clamped to [0, 1],
    # mapped linearly from the u_hi knee to 1.0
    util = np.sum(np.where(alive, np.minimum(load, np.float32(1.0)),
                           np.float32(0.0))) / \
        np.maximum(np.sum(alive.astype(np.float32)), np.float32(1.0))
    brown = np.clip((util - u_hi) / np.maximum(np.float32(1.0) - u_hi,
                                               np.float32(1e-6)),
                    np.float32(0.0), np.float32(1.0))
    eff_cost = cost * (np.float32(1.0) + beta * brown *
                       (np.float32(1.0) - is_edge.astype(np.float32)))
    overshoot = np.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    return feasible, eff_cost, overshoot


def decide_pair_resilient_jnp(genome: jnp.ndarray, *,
                              ttft_deadline: jnp.ndarray,
                              tpot_deadline: jnp.ndarray, up: jnp.ndarray,
                              prefill: jnp.ndarray, tpot: jnp.ndarray,
                              cost: jnp.ndarray, queue_len: jnp.ndarray,
                              arrays: ClusterArrays) -> jnp.ndarray:
    """Cheapest feasible pair by brownout-inflated cost; if no pair is
    feasible, minimize the worst normalized deadline overshoot."""
    gamma = genome[0]
    kappa = genome[1]
    beta = genome[2]
    u_hi = genome[3]
    q = queue_len.astype(jnp.float32)
    alive = q < _DEAD_QUEUE
    load = q / arrays.node_conc.astype(jnp.float32)
    est_wait = kappa * load[arrays.pair_node]
    est_ttft = up + est_wait + prefill
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= jnp.minimum(gamma, 1.0) * tpot_deadline)
    util = jnp.sum(jnp.where(alive, jnp.minimum(load, 1.0), 0.0)) / \
        jnp.maximum(jnp.sum(alive.astype(jnp.float32)), 1.0)
    brown = jnp.clip((util - u_hi) / jnp.maximum(1.0 - u_hi, 1e-6), 0.0, 1.0)
    is_edge = arrays.pair_is_edge.astype(jnp.float32)
    eff_cost = cost * (1.0 + beta * brown * (1.0 - is_edge))
    any_ok = jnp.any(feasible)
    cheapest = jnp.argmin(jnp.where(feasible, eff_cost, jnp.inf))
    overshoot = jnp.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    least_bad = jnp.argmin(overshoot)
    return jnp.where(any_ok, cheapest, least_bad).astype(jnp.int32)


def decide_pair_resilient_py(genome: Sequence[float], *,
                             ttft_deadline: float, tpot_deadline: float,
                             up: np.ndarray, prefill: np.ndarray,
                             tpot: np.ndarray, cost: np.ndarray,
                             queue_len: Sequence[int],
                             arrays: ClusterArrays) -> int:
    """Reference numpy transcription of the resilient decision (oracle)."""
    node = np.asarray(arrays.pair_node)
    conc = np.asarray(arrays.node_conc)
    is_edge = np.asarray(arrays.pair_is_edge)
    feasible, eff_cost, overshoot = _resilient_scores_np(
        np.asarray(genome, np.float32),
        np.float32(ttft_deadline), np.float32(tpot_deadline),
        np.asarray(up, np.float32), np.asarray(prefill, np.float32),
        np.asarray(tpot, np.float32), np.asarray(cost, np.float32),
        np.asarray(queue_len), node, conc, is_edge)
    if feasible.any():
        return int(np.argmin(np.where(feasible, eff_cost, np.inf)))
    return int(np.argmin(overshoot))


class ResilientPolicy(RoutingPolicy):
    """Registered wrapper over the resilient decision pair."""

    name = "resilient"
    genome_spec = GenomeSpec(names=RESILIENT_PARAM_NAMES,
                             lo=RESILIENT_BOUNDS_LO, hi=RESILIENT_BOUNDS_HI,
                             defaults=RESILIENT_DEFAULTS)
    requires = frozenset({"estimates", "deadlines"})

    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        return decide_pair_resilient_jnp(
            genome, ttft_deadline=inp.ttft_deadline,
            tpot_deadline=inp.tpot_deadline, up=inp.up, prefill=inp.prefill,
            tpot=inp.tpot, cost=inp.cost, queue_len=inp.queue_len,
            arrays=arrays)

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        return decide_pair_resilient_py(
            genome, ttft_deadline=float(inp.ttft_deadline),
            tpot_deadline=float(inp.tpot_deadline), up=inp.up,
            prefill=inp.prefill, tpot=inp.tpot, cost=inp.cost,
            queue_len=inp.queue_len, arrays=arrays)


register_policy(ResilientPolicy())
