"""Direct-assignment policy (paper §IV-B.1): one integer gene per request.

The genome *is* the routing solution — gene i selects request i's
(node, model) pair. This is the discrete NSGA-II encoding (uniform-swap
crossover + reassignment mutation); it has no runtime-router form because
the genome length is trace-dependent (``GenomeSpec.per_request``).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import register_policy
from .base import GenomeSpec, PolicyInputs, RoutingPolicy


class DirectPolicy(RoutingPolicy):
    name = "direct"
    genome_spec = GenomeSpec(discrete=True, per_request=True)
    requires = frozenset()

    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        return genome[inp.index].astype(jnp.int32)

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        return int(genome[int(inp.index)])


register_policy(DirectPolicy())
