"""Cache-affinity decision policy (prefix-reuse extension).

The SLO decision with a cache-hit-probability term: the expected
cached-prefix fraction per pair discounts both the prefill term of the TTFT
estimate and the prompt part of the cost, and ρ adds an affinity bonus for
pairs already holding the prefix. Genome: [γ, κ, ρ].
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from ...cluster.spec import ClusterArrays
from . import register_policy
from .base import GenomeSpec, PolicyInputs, RoutingPolicy

AFFINITY_PARAM_NAMES = ("gamma", "kappa", "rho")

# γ, κ as in the SLO genome; ρ in [0, 4] weighs expected prefix-cache hits
# beyond their realized discount (stickiness: a hit now also keeps the
# session's *future* turns cheap on the same node).
AFFINITY_BOUNDS_LO = np.array([0.3, 0.0, 0.0], np.float32)
AFFINITY_BOUNDS_HI = np.array([1.1, 20.0, 4.0], np.float32)
AFFINITY_DEFAULTS = np.array([0.9, 3.0, 1.0], np.float32)

# cached prompt tokens bill at this fraction of the full input price
# (Anthropic/OpenAI-style cached-input discount; vLLM skips the compute)
CACHED_TOKEN_PRICE_FACTOR = 0.1


def decide_pair_affinity_jnp(genome: jnp.ndarray, *,
                             ttft_deadline: jnp.ndarray,
                             tpot_deadline: jnp.ndarray, up: jnp.ndarray,
                             prefill: jnp.ndarray, tpot: jnp.ndarray,
                             cost: jnp.ndarray, prompt_cost: jnp.ndarray,
                             hit_frac: jnp.ndarray, queue_len: jnp.ndarray,
                             arrays: ClusterArrays) -> jnp.ndarray:
    """SLO decision with a cache-hit-probability term: the expected
    cached-prefix fraction (``hit_frac``, per pair) discounts both the
    prefill term of the TTFT estimate and the prompt part of the cost, and
    ``ρ`` adds an affinity bonus for pairs already holding the prefix.
    ``prompt_cost`` is the request's (n_pairs,) prompt-only cost row.
    """
    gamma, kappa, rho = genome[0], genome[1], genome[2]
    load = queue_len.astype(jnp.float32) / arrays.node_conc.astype(jnp.float32)
    est_wait = kappa * load[arrays.pair_node]
    prefill_eff = prefill * (1.0 - hit_frac)
    est_ttft = up + est_wait + prefill_eff
    cost_eff = cost - hit_frac * (1.0 - CACHED_TOKEN_PRICE_FACTOR) * prompt_cost
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= jnp.minimum(gamma, 1.0) * tpot_deadline)
    score = cost_eff - rho * hit_frac * prompt_cost
    any_ok = jnp.any(feasible)
    best = jnp.argmin(jnp.where(feasible, score, jnp.inf))
    overshoot = jnp.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    least_bad = jnp.argmin(overshoot)
    return jnp.where(any_ok, best, least_bad).astype(jnp.int32)


def decide_pair_affinity_py(genome: Sequence[float], *, ttft_deadline: float,
                            tpot_deadline: float, up: np.ndarray,
                            prefill: np.ndarray, tpot: np.ndarray,
                            cost: np.ndarray, prompt_cost: np.ndarray,
                            hit_frac: np.ndarray, queue_len: Sequence[int],
                            arrays: ClusterArrays) -> int:
    """Reference numpy transcription of the affinity decision (test oracle);
    mirrors the jnp path op-for-op so argmin tie-breaking is identical."""
    g = np.asarray(genome, np.float32)
    gamma, kappa, rho = np.float32(g[0]), np.float32(g[1]), np.float32(g[2])
    node = np.asarray(arrays.pair_node)
    conc = np.asarray(arrays.node_conc)
    up = np.asarray(up, np.float32)
    prefill = np.asarray(prefill, np.float32)
    tpot = np.asarray(tpot, np.float32)
    cost = np.asarray(cost, np.float32)
    prompt_cost = np.asarray(prompt_cost, np.float32)
    hit_frac = np.asarray(hit_frac, np.float32)
    ttft_deadline = np.float32(ttft_deadline)
    tpot_deadline = np.float32(tpot_deadline)

    load = np.asarray(queue_len).astype(np.float32) / conc.astype(np.float32)
    est_wait = kappa * load[node]
    prefill_eff = prefill * (np.float32(1.0) - hit_frac)
    est_ttft = up + est_wait + prefill_eff
    cost_eff = cost - hit_frac * np.float32(
        1.0 - CACHED_TOKEN_PRICE_FACTOR) * prompt_cost
    feasible = (est_ttft <= gamma * ttft_deadline) & \
               (tpot <= np.minimum(gamma, np.float32(1.0)) * tpot_deadline)
    score = cost_eff - rho * hit_frac * prompt_cost
    if feasible.any():
        return int(np.argmin(np.where(feasible, score, np.inf)))
    overshoot = np.maximum(est_ttft / ttft_deadline, tpot / tpot_deadline)
    return int(np.argmin(overshoot))


class AffinityPolicy(RoutingPolicy):
    """Registered wrapper over the cache-affinity decision pair."""

    name = "affinity"
    genome_spec = GenomeSpec(names=AFFINITY_PARAM_NAMES,
                             lo=AFFINITY_BOUNDS_LO, hi=AFFINITY_BOUNDS_HI,
                             defaults=AFFINITY_DEFAULTS)
    requires = frozenset({"estimates", "deadlines", "cache"})

    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        return decide_pair_affinity_jnp(
            genome, ttft_deadline=inp.ttft_deadline,
            tpot_deadline=inp.tpot_deadline, up=inp.up, prefill=inp.prefill,
            tpot=inp.tpot, cost=inp.cost, prompt_cost=inp.prompt_cost,
            hit_frac=inp.hit_frac, queue_len=inp.queue_len, arrays=arrays)

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        return decide_pair_affinity_py(
            genome, ttft_deadline=float(inp.ttft_deadline),
            tpot_deadline=float(inp.tpot_deadline), up=inp.up,
            prefill=inp.prefill, tpot=inp.tpot, cost=inp.cost,
            prompt_cost=inp.prompt_cost, hit_frac=inp.hit_frac,
            queue_len=inp.queue_len, arrays=arrays)


register_policy(AffinityPolicy())
