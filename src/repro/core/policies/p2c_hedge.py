"""Power-of-two-choices routing with hedge-cost awareness (new policy,
written *only* against the RoutingPolicy registry — no evaluator/router/DES
edits were needed to ship it).

Classic p2c load balancing (Mitzenmacher) samples two candidate servers and
sends the request to the better one: near-optimal load spread at O(1)
decision cost, and no herd behaviour because different requests sample
different candidate sets. Here the "better" criterion is hedge-cost aware:
the serving scheduler duplicates stragglers onto backup pairs
(``serving.scheduler`` hedging), so a loaded node does not just queue — it
*doubles spend* with probability growing in its load. A candidate's
effective cost is therefore

    cost × (1 + h · min(load, 1))        (h = genome hedge weight)

and among deadline-feasible candidates the lower effective cost wins; with
no feasible candidate, the lower worst-case deadline overshoot wins
(graceful degradation, mirroring the SLO policy).

Candidate sampling must be *deterministic and identical* across the three
implementations (JAX scan, DES oracles, runtime router), so candidates come
from a counter-based uint32 hash of the request index — no RNG state, no
host/device divergence. Genome: [γ (deadline headroom), κ (wait s/load),
h (hedge-cost weight)].
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import register_policy
from .base import GenomeSpec, PolicyInputs, RoutingPolicy

P2C_PARAM_NAMES = ("gamma", "kappa", "hedge_w")
P2C_BOUNDS_LO = np.array([0.3, 0.0, 0.0], np.float32)
P2C_BOUNDS_HI = np.array([1.1, 20.0, 4.0], np.float32)
P2C_DEFAULTS = np.array([0.9, 3.0, 1.0], np.float32)

_MIX_C = 0x45D9F3B  # splitmix-style 32-bit finalizer multiplier


def _mix32_py(x: int) -> int:
    """uint32 avalanche hash — Python-int reference (masked to 32 bits so it
    is bit-identical to the wrapping uint32 arithmetic of the jnp twin)."""
    x &= 0xFFFFFFFF
    x = (((x >> 16) ^ x) * _MIX_C) & 0xFFFFFFFF
    x = (((x >> 16) ^ x) * _MIX_C) & 0xFFFFFFFF
    return ((x >> 16) ^ x) & 0xFFFFFFFF


def _mix32_jnp(x):
    x = x.astype(jnp.uint32)
    x = ((x >> 16) ^ x) * jnp.uint32(_MIX_C)
    x = ((x >> 16) ^ x) * jnp.uint32(_MIX_C)
    return (x >> 16) ^ x


class P2CHedgePolicy(RoutingPolicy):
    name = "p2c-hedge"
    genome_spec = GenomeSpec(names=P2C_PARAM_NAMES, lo=P2C_BOUNDS_LO,
                             hi=P2C_BOUNDS_HI, defaults=P2C_DEFAULTS)
    requires = frozenset({"estimates", "deadlines"})

    def decide_jnp(self, genome, inp: PolicyInputs, arrays, state):
        gamma, kappa, h = genome[0], genome[1], genome[2]
        n_pairs = inp.up.shape[0]
        i = inp.index.astype(jnp.uint32)
        c1 = (_mix32_jnp(jnp.uint32(2) * i + jnp.uint32(1))
              % jnp.uint32(n_pairs)).astype(jnp.int32)
        c2 = (_mix32_jnp(jnp.uint32(2) * i + jnp.uint32(2))
              % jnp.uint32(n_pairs)).astype(jnp.int32)

        load = (inp.queue_len.astype(jnp.float32)
                / arrays.node_conc.astype(jnp.float32))
        pair_load = load[arrays.pair_node]
        est_ttft = inp.up + kappa * pair_load + inp.prefill
        feasible = (est_ttft <= gamma * inp.ttft_deadline) & \
                   (inp.tpot <= jnp.minimum(gamma, 1.0) * inp.tpot_deadline)
        eff_cost = inp.cost * (1.0 + h * jnp.minimum(pair_load, 1.0))
        overshoot = jnp.maximum(est_ttft / inp.ttft_deadline,
                                inp.tpot / inp.tpot_deadline)

        f1, f2 = feasible[c1], feasible[c2]
        # both feasible -> cheaper effective cost; one feasible -> it;
        # neither -> smaller overshoot. Ties keep candidate 1.
        pick2 = jnp.where(f1 & f2, eff_cost[c2] < eff_cost[c1],
                          jnp.where(f1, False,
                                    jnp.where(f2, True,
                                              overshoot[c2] < overshoot[c1])))
        return jnp.where(pick2, c2, c1).astype(jnp.int32)

    def decide_py(self, genome, inp: PolicyInputs, arrays, state) -> int:
        g = np.asarray(genome, np.float32)
        gamma, kappa, h = np.float32(g[0]), np.float32(g[1]), np.float32(g[2])
        up = np.asarray(inp.up, np.float32)
        prefill = np.asarray(inp.prefill, np.float32)
        tpot = np.asarray(inp.tpot, np.float32)
        cost = np.asarray(inp.cost, np.float32)
        ttft_dl = np.float32(inp.ttft_deadline)
        tpot_dl = np.float32(inp.tpot_deadline)
        n_pairs = len(up)
        i = int(inp.index)
        c1 = _mix32_py(2 * i + 1) % n_pairs
        c2 = _mix32_py(2 * i + 2) % n_pairs

        node = np.asarray(arrays.pair_node)
        conc = np.asarray(arrays.node_conc)
        load = np.asarray(inp.queue_len).astype(np.float32) / \
            conc.astype(np.float32)
        pair_load = load[node]
        est_ttft = up + kappa * pair_load + prefill
        feasible = (est_ttft <= gamma * ttft_dl) & \
                   (tpot <= np.minimum(gamma, np.float32(1.0)) * tpot_dl)
        eff_cost = cost * (np.float32(1.0)
                           + h * np.minimum(pair_load, np.float32(1.0)))
        overshoot = np.maximum(est_ttft / ttft_dl, tpot / tpot_dl)

        f1, f2 = bool(feasible[c1]), bool(feasible[c2])
        if f1 and f2:
            pick2 = bool(eff_cost[c2] < eff_cost[c1])
        elif f1:
            pick2 = False
        elif f2:
            pick2 = True
        else:
            pick2 = bool(overshoot[c2] < overshoot[c1])
        return c2 if pick2 else c1


register_policy(P2CHedgePolicy())
