"""Vectorized trace evaluation for NSGA-II fitness (paper §IV-B.2).

The evaluator turns a routing decision for every request in the trace into the
three objectives (RQ, C, RT) of Eqs. (2)–(4). Two execution models:

* ``mode="eq5"`` — the paper's Eq. (5) exactly: RT_i = upload + T_infer +
  download, no queueing (this is what Table II measures at concurrency 1).
* ``mode="queued"`` — closed-loop with G concurrent clients and per-node
  execution slots (capacity C_j): requests wait for a free slot, which
  reproduces the Fig. 4 concurrency behaviour and enforces the §III resource
  constraint (a policy that floods one node accrues unbounded waits →
  constraint violation via the W_MAX stability bound).

Beyond the paper, the evaluator does **phase-split accounting**: every
request's response time is decomposed into TTFT (upload + queue wait +
prefill — time to first token) and TPOT (decode seconds per output token),
mirroring the prefill/decode split of ``serving.engine``. With per-request
deadlines attached to the trace (``workload.slo``), ``make_fitness`` can
expose SLO violation as a fourth objective ("qoe") and ``_run_trace`` can run
the SLO-aware policy (``policy="slo"``) whose in-scan decisions depend on the
live queue *and* the request's deadline pair.

Everything static per (trace × cluster) is precomputed into ``EvalTables``
(I × n_pairs matrices); the jitted scan only resolves queue dynamics, so a
population×trace evaluation is one fused XLA program:

    vmap over P policies ∘ lax.scan over I requests ∘ O(n_nodes) queue update

For **threshold genomes** the routing decision (Algorithm 2) happens *inside*
the scan because it depends on live queue lengths; for **slo genomes** the
decision additionally reads the deadline tables; for **direct genomes** the
assignment vector is the genome itself.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..cluster.spec import ClusterArrays, ClusterSpec
from ..workload.trace import Trace
from .objectives import aggregate_qoe, slo_ok
from .policy import decide_pair_jnp, decide_pair_slo_jnp

RESP_BYTES_PER_TOKEN = 4.2  # avg UTF-8 payload bytes per generated token

POLICY_KINDS = ("direct", "threshold", "slo")


class EvalTables(NamedTuple):
    """(I, n_pairs) static tables + per-request arrays for the evaluator."""

    quality: jnp.ndarray      # realized q(r_i, pair)
    cost: jnp.ndarray         # Eq. 3 per-request cost
    service: jnp.ndarray      # T_infer (prefill + decode)
    up_time: jnp.ndarray      # Q_size/B_up + latency_up
    down_time: jnp.ndarray    # R_size/B_down + latency_down
    # phase split (QoE accounting)
    prefill_time: jnp.ndarray  # (I, n_pairs) prompt/prefill_tps
    tpot: jnp.ndarray          # (n_pairs,) decode seconds per output token
    # per-request features for in-scan routing (threshold policies)
    complexity: jnp.ndarray   # (I,)
    pred_category: jnp.ndarray  # (I,) int32 (0=code, 1=math, 2=general)
    pred_conf: jnp.ndarray    # (I,)
    # per-request QoE contract (+inf when the trace carries no SLOs)
    ttft_deadline: jnp.ndarray  # (I,)
    tpot_deadline: jnp.ndarray  # (I,)


def request_pair_estimates(prompt_tokens: float, resp_tokens_mean: float,
                           query_bytes: float, arrays: ClusterArrays
                           ) -> dict:
    """Per-pair phase/cost estimates for ONE request (numpy, router hot path).

    Returns float32 (n_pairs,) vectors ``up``, ``prefill``, ``tpot``,
    ``cost`` using the same formulas as ``build_tables`` so the runtime
    router's SLO decisions agree with the offline evaluator.
    """
    verb = np.asarray(arrays.pair_verbosity, np.float32)
    resp_tokens = np.maximum(np.round(np.float32(resp_tokens_mean) * verb), 1.0)
    price = np.asarray(arrays.pair_price, np.float32)
    cost = (np.float32(prompt_tokens) + resp_tokens) / 1e6 * price
    prefill = np.float32(prompt_tokens) / np.asarray(arrays.pair_prefill_tps,
                                                     np.float32)
    tpot = np.float32(1.0) / np.asarray(arrays.pair_decode_tps, np.float32)
    node = np.asarray(arrays.pair_node)
    up = (np.float32(query_bytes) / np.asarray(arrays.node_bw_up,
                                               np.float32)[node]
          + np.asarray(arrays.node_lat_up, np.float32)[node])
    return {"up": up.astype(np.float32), "prefill": prefill.astype(np.float32),
            "tpot": tpot.astype(np.float32), "cost": cost.astype(np.float32)}


def build_tables(trace: Trace, cluster: ClusterSpec, seed: int = 0
                 ) -> Tuple[EvalTables, ClusterArrays]:
    """Precompute all queue-independent quantities."""
    arrays = cluster.to_arrays()
    I = trace.n_requests
    Pn = arrays.n_pairs

    task = trace.task                          # (I,)
    prompt = trace.prompt_tokens.astype(np.float32)
    resp_mean = trace.resp_tokens_mean
    difficulty = trace.difficulty
    qbytes = trace.query_bytes

    verb = np.asarray(arrays.pair_verbosity)   # (Pn,)
    resp_tokens = np.maximum(np.round(resp_mean[:, None] * verb[None, :]), 1.0)

    price = np.asarray(arrays.pair_price)
    total_tokens = prompt[:, None] + resp_tokens
    cost = total_tokens / 1e6 * price[None, :]                     # Eq. 3

    prefill = prompt[:, None] / np.asarray(arrays.pair_prefill_tps)[None, :]
    decode = resp_tokens / np.asarray(arrays.pair_decode_tps)[None, :]
    service = prefill + decode
    tpot = 1.0 / np.asarray(arrays.pair_decode_tps)

    node = np.asarray(arrays.pair_node)
    up = (qbytes[:, None] / np.asarray(arrays.node_bw_up)[node][None, :]
          + np.asarray(arrays.node_lat_up)[node][None, :])
    resp_bytes = resp_tokens * RESP_BYTES_PER_TOKEN
    down = (resp_bytes / np.asarray(arrays.node_bw_down)[node][None, :]
            + np.asarray(arrays.node_lat_down)[node][None, :])

    base_q = np.asarray(arrays.pair_base_quality)  # (Pn, n_tasks)
    slope = np.asarray(arrays.pair_diff_slope)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 777]))
    noise = rng.normal(0.0, 0.05, size=(I, Pn)).astype(np.float32)
    quality = np.clip(
        base_q.T[task, :] + slope[None, :] * (0.5 - difficulty[:, None]) + noise,
        0.0, 1.0)

    if trace.has_slos:
        ttft_dl = trace.ttft_deadline
        tpot_dl = trace.tpot_deadline
    else:
        ttft_dl = np.full(I, np.inf, np.float32)
        tpot_dl = np.full(I, np.inf, np.float32)

    tables = EvalTables(
        quality=jnp.asarray(quality, jnp.float32),
        cost=jnp.asarray(cost, jnp.float32),
        service=jnp.asarray(service, jnp.float32),
        up_time=jnp.asarray(up, jnp.float32),
        down_time=jnp.asarray(down, jnp.float32),
        prefill_time=jnp.asarray(prefill, jnp.float32),
        tpot=jnp.asarray(tpot, jnp.float32),
        complexity=jnp.asarray(trace.complexity, jnp.float32),
        pred_category=jnp.asarray(trace.pred_category, jnp.int32),
        pred_conf=jnp.asarray(trace.pred_conf, jnp.float32),
        ttft_deadline=jnp.asarray(ttft_dl, jnp.float32),
        tpot_deadline=jnp.asarray(tpot_dl, jnp.float32),
    )
    return tables, arrays


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    concurrency: int = 1          # G closed-loop clients
    mode: str = "queued"          # "eq5" | "queued"
    w_max: float = 30.0           # stability bound: wait beyond this = violation

    def __post_init__(self):
        assert self.mode in ("eq5", "queued")


class EvalResult(NamedTuple):
    q: jnp.ndarray        # (I,) realized quality
    cost: jnp.ndarray     # (I,)
    rt: jnp.ndarray       # (I,)
    assign: jnp.ndarray   # (I,) chosen pair per request
    violation: jnp.ndarray  # scalar
    ttft: jnp.ndarray     # (I,) time to first token (up + wait + prefill)
    tpot: jnp.ndarray     # (I,) decode seconds per output token


def _max_conc(arrays: ClusterArrays) -> int:
    return int(np.max(np.asarray(arrays.node_conc)))


@functools.partial(jax.jit, static_argnames=("policy", "cfg", "n_slots"))
def _run_trace(genome: jnp.ndarray, policy: str, tables: EvalTables,
               arrays: ClusterArrays, cfg: EvalConfig,
               n_slots: int) -> EvalResult:
    assert policy in POLICY_KINDS
    I = tables.quality.shape[0]
    G = cfg.concurrency

    # slot_free[n, s] = time when slot s of node n becomes free;
    # slots beyond a node's concurrency are pinned at +inf (never chosen).
    slot_ids = jnp.arange(n_slots)[None, :]
    slot_valid = slot_ids < arrays.node_conc[:, None]
    init_slots = jnp.where(slot_valid, 0.0, jnp.inf)
    init_clients = jnp.zeros((G,), jnp.float32)

    def body(carry, i):
        slot_free, client_ready = carry
        arrival = client_ready[i % G]

        # monitor view: busy slots per node at arrival (the q_j feature)
        busy = jnp.sum(jnp.where(slot_valid, slot_free > arrival, False),
                       axis=1).astype(jnp.int32)

        if policy == "threshold":
            pair = decide_pair_jnp(
                genome,
                complexity=tables.complexity[i],
                pred_category=tables.pred_category[i],
                pred_conf=tables.pred_conf[i],
                queue_len=busy, arrays=arrays)
        elif policy == "slo":
            pair = decide_pair_slo_jnp(
                genome,
                ttft_deadline=tables.ttft_deadline[i],
                tpot_deadline=tables.tpot_deadline[i],
                up=tables.up_time[i], prefill=tables.prefill_time[i],
                tpot=tables.tpot, cost=tables.cost[i],
                queue_len=busy, arrays=arrays)
        else:
            pair = genome[i]

        node = arrays.pair_node[pair]
        up = tables.up_time[i, pair]
        down = tables.down_time[i, pair]
        service = tables.service[i, pair]
        prefill = tables.prefill_time[i, pair]

        if cfg.mode == "eq5":
            rt = up + service + down                    # Eq. (5) verbatim
            completion = arrival + rt
            wait = 0.0
            new_slot_free = slot_free
        else:
            ready = arrival + up
            slots_n = slot_free[node]
            s = jnp.argmin(slots_n)
            start = jnp.maximum(ready, slots_n[s])
            wait = start - ready
            finish = start + service
            completion = finish + down
            rt = completion - arrival
            new_slot_free = slot_free.at[node, s].set(finish)

        ttft = up + wait + prefill
        client_ready = client_ready.at[i % G].set(completion)
        out = (tables.quality[i, pair], tables.cost[i, pair], rt, pair,
               jnp.maximum(wait - cfg.w_max, 0.0), ttft, tables.tpot[pair])
        return (new_slot_free, client_ready), out

    (_, _), (q, cost, rt, assign, excess, ttft, tpot) = jax.lax.scan(
        body, (init_slots, init_clients), jnp.arange(I))
    return EvalResult(q=q, cost=cost, rt=rt, assign=assign,
                      violation=jnp.sum(excess), ttft=ttft, tpot=tpot)


class TraceEvaluator:
    """Evaluate routing decisions over a fixed (trace × cluster)."""

    def __init__(self, trace: Trace, cluster: ClusterSpec,
                 cfg: EvalConfig = EvalConfig(), seed: int = 0):
        self.trace = trace
        self.cluster = cluster
        self.cfg = cfg
        self.tables, self.arrays = build_tables(trace, cluster, seed=seed)
        self.n_slots = _max_conc(self.arrays)

    # -- single policy ------------------------------------------------------
    def run_assignment(self, assign: jnp.ndarray) -> EvalResult:
        return _run_trace(jnp.asarray(assign, jnp.int32), "direct",
                          self.tables, self.arrays, self.cfg, self.n_slots)

    def run_thresholds(self, thresholds: jnp.ndarray) -> EvalResult:
        return _run_trace(jnp.asarray(thresholds, jnp.float32), "threshold",
                          self.tables, self.arrays, self.cfg, self.n_slots)

    def run_slo_policy(self, params: jnp.ndarray) -> EvalResult:
        """Run the SLO-aware policy (genome = [γ, κ], see core.policy)."""
        return _run_trace(jnp.asarray(params, jnp.float32), "slo",
                          self.tables, self.arrays, self.cfg, self.n_slots)

    # -- population fitness (for NSGA2) --------------------------------------
    def make_fitness(self, genome: str, objectives: str = "paper"):
        """Return FitnessFn mapping (P, D) genomes -> ((P, M), (P,)).

        genome: "continuous" (Algorithm-2 thresholds), "discrete" (direct
        assignment), or "slo" ([γ, κ] SLO policy). objectives: "paper" for
        the 3-vector (RQ, C, RT); "qoe" appends the SLO violation rate as a
        4th minimized objective (requires a trace with deadlines attached).
        """
        assert objectives in ("paper", "qoe")
        assert objectives != "qoe" or self.trace.has_slos, \
            "qoe objectives need a trace with SLOs (workload.slo.attach_slos)"
        policy = {"continuous": "threshold", "discrete": "direct",
                  "slo": "slo"}[genome]

        def run_one(g):
            g = g if policy == "direct" else g.astype(jnp.float32)
            res = _run_trace(g, policy, self.tables, self.arrays, self.cfg,
                             self.n_slots)
            if objectives == "qoe":
                F = aggregate_qoe(res.q, res.cost, res.rt, res.ttft, res.tpot,
                                  self.tables.ttft_deadline,
                                  self.tables.tpot_deadline).stack()
            else:
                F = jnp.stack([jnp.mean(1.0 - res.q), jnp.mean(res.cost),
                               jnp.mean(res.rt)])
            return F, res.violation

        def fitness(genomes, key):
            del key
            F, viol = jax.vmap(run_one)(genomes)
            return F, viol

        return fitness

    # -- reporting ------------------------------------------------------------
    def summarize(self, res: EvalResult) -> dict:
        out = {
            "avg_quality": float(jnp.mean(res.q)),
            "avg_response_time": float(jnp.mean(res.rt)),
            "avg_cost": float(jnp.mean(res.cost)),
            "RQ": float(jnp.mean(1.0 - res.q)),
            "violation": float(res.violation),
            "avg_ttft": float(jnp.mean(res.ttft)),
            "avg_tpot": float(jnp.mean(res.tpot)),
        }
        if self.trace.has_slos:
            ok = slo_ok(res.ttft, res.tpot, self.tables.ttft_deadline,
                        self.tables.tpot_deadline)
            out["slo_attainment"] = float(jnp.mean(ok.astype(jnp.float32)))
        return out

    def per_dataset_quality(self, res: EvalResult) -> dict:
        from ..cluster.spec import TASKS
        out = {}
        task = jnp.asarray(self.trace.task)
        for t, name in enumerate(TASKS):
            mask = task == t
            out[name] = float(jnp.sum(jnp.where(mask, res.q, 0.0))
                              / jnp.maximum(jnp.sum(mask), 1))
        return out
