"""NSGA-II (Deb et al. 2002) fully vectorized in JAX.

This is the paper's §IV optimizer: a population of routing policies evolved
with non-dominated sorting + crowding distance, binary tournament selection,
crossover and mutation. Two genome encodings are supported, matching the two
policy representations in the paper:

* **continuous** (threshold genome, §IV-B.6): D decision variables in
  ``[lo, hi]`` — SBX crossover + polynomial mutation. This is what the runtime
  rule-based router consumes (θ_d,code, θ_d,math, θ_d,general, θ_q, θ_t,code,
  θ_t,math).
* **discrete** (direct assignment genome, §IV-B.1): one integer gene per
  request selecting a (node, model) pair — uniform-swap crossover ("swapping
  node-LLM pairs for a subset of requests") + random reassignment mutation.

The whole generation step is a single jitted function; ``evolve`` runs a
Python loop for logging, ``evolve_scan`` runs the entire optimization as one
``lax.scan`` (used by the perf benchmarks).

Constraints are handled with the standard constrained-domination trick folded
into a penalty: the fitness function may return a violation vector alongside
objectives; infeasible individuals get all objectives shifted by
``violation * PENALTY`` which makes every feasible point dominate them while
still ordering infeasible points by violation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .pareto import crowding_distance, non_dominated_sort

PENALTY = 1e6


@dataclasses.dataclass(frozen=True)
class NSGA2Config:
    """Hyper-parameters (paper §V-A: P=100, T=100, pc=0.8, pm=0.1)."""

    pop_size: int = 100
    n_generations: int = 100
    crossover_prob: float = 0.8
    mutation_prob: float = 0.1
    eta_crossover: float = 15.0
    eta_mutation: float = 20.0
    genome: str = "continuous"  # "continuous" | "discrete"
    # continuous bounds (D,) arrays; discrete cardinality + genome length
    lo: Optional[jnp.ndarray] = None
    hi: Optional[jnp.ndarray] = None
    n_choices: int = 0
    # number of genes D for the default *discrete* init (e.g. n_requests for
    # direct-assignment genomes); continuous genomes take D from lo/hi
    genome_length: int = 0

    def __post_init__(self):
        assert self.pop_size % 2 == 0, "pop_size must be even"
        assert self.genome in ("continuous", "discrete")

    @property
    def n_genes(self) -> int:
        """Genome dimensionality D implied by the config."""
        if self.genome == "continuous":
            assert self.lo is not None, "continuous genome requires bounds"
            return int(self.lo.shape[0])
        assert self.genome_length > 0, \
            "discrete genome requires genome_length (or a custom init_fn)"
        return self.genome_length


class NSGA2State(NamedTuple):
    genomes: jax.Array     # (P, D) float32 or int32
    F: jax.Array           # (P, M) penalized objectives
    F_raw: jax.Array       # (P, M) unpenalized objectives
    violation: jax.Array   # (P,)
    rank: jax.Array        # (P,)
    crowd: jax.Array       # (P,)
    key: jax.Array
    generation: jax.Array  # scalar int32


# FitnessFn: (genomes (P, D), key) -> (F (P, M), violation (P,))
FitnessFn = Callable[[jax.Array, jax.Array], Tuple[jax.Array, jax.Array]]


def _penalize(F: jax.Array, violation: jax.Array) -> jax.Array:
    return F + (violation[:, None] > 0) * (PENALTY + violation[:, None] * PENALTY)


# ---------------------------------------------------------------------------
# Variation operators
# ---------------------------------------------------------------------------

def sbx_crossover(key: jax.Array, p1: jax.Array, p2: jax.Array,
                  lo: jax.Array, hi: jax.Array, pc: float, eta: float
                  ) -> Tuple[jax.Array, jax.Array]:
    """Simulated binary crossover on (n_pairs, D) parent blocks."""
    k_pair, k_gene, k_u = jax.random.split(key, 3)
    n_pairs, D = p1.shape
    u = jax.random.uniform(k_u, (n_pairs, D))
    beta = jnp.where(
        u <= 0.5,
        (2.0 * u) ** (1.0 / (eta + 1.0)),
        (1.0 / (2.0 * (1.0 - u))) ** (1.0 / (eta + 1.0)),
    )
    c1 = 0.5 * ((1.0 + beta) * p1 + (1.0 - beta) * p2)
    c2 = 0.5 * ((1.0 - beta) * p1 + (1.0 + beta) * p2)
    # per-gene 0.5 exchange, per-pair pc gate
    do_pair = jax.random.uniform(k_pair, (n_pairs, 1)) < pc
    do_gene = jax.random.uniform(k_gene, (n_pairs, D)) < 0.5
    apply = do_pair & do_gene
    c1 = jnp.where(apply, c1, p1)
    c2 = jnp.where(apply, c2, p2)
    return jnp.clip(c1, lo, hi), jnp.clip(c2, lo, hi)


def polynomial_mutation(key: jax.Array, x: jax.Array, lo: jax.Array,
                        hi: jax.Array, pm: float, eta: float) -> jax.Array:
    """Polynomial mutation on (P, D)."""
    k_gate, k_u = jax.random.split(key)
    u = jax.random.uniform(k_u, x.shape)
    delta = jnp.where(
        u < 0.5,
        (2.0 * u) ** (1.0 / (eta + 1.0)) - 1.0,
        1.0 - (2.0 * (1.0 - u)) ** (1.0 / (eta + 1.0)),
    )
    mutated = x + delta * (hi - lo)
    gate = jax.random.uniform(k_gate, x.shape) < pm
    return jnp.clip(jnp.where(gate, mutated, x), lo, hi)


def uniform_swap_crossover(key: jax.Array, p1: jax.Array, p2: jax.Array,
                           pc: float) -> Tuple[jax.Array, jax.Array]:
    """Paper §IV-B.4: swap node-LLM pairs for a subset of requests."""
    k_pair, k_gene = jax.random.split(key)
    n_pairs, D = p1.shape
    do_pair = jax.random.uniform(k_pair, (n_pairs, 1)) < pc
    swap = (jax.random.uniform(k_gene, (n_pairs, D)) < 0.5) & do_pair
    c1 = jnp.where(swap, p2, p1)
    c2 = jnp.where(swap, p1, p2)
    return c1, c2


def reassignment_mutation(key: jax.Array, x: jax.Array, pm: float,
                          n_choices: int) -> jax.Array:
    """Paper §IV-B.4: reassign a small fraction of requests to other pairs."""
    k_gate, k_new = jax.random.split(key)
    gate = jax.random.uniform(k_gate, x.shape) < pm
    fresh = jax.random.randint(k_new, x.shape, 0, n_choices, dtype=x.dtype)
    return jnp.where(gate, fresh, x)


# ---------------------------------------------------------------------------
# Warm start
# ---------------------------------------------------------------------------

def archive_init(archive: jax.Array, cfg: NSGA2Config
                 ) -> Callable[[jax.Array], jax.Array]:
    """``init_fn`` seeding a population from an elite archive (warm start).

    The first ``min(len(archive), pop_size)`` individuals are copied from the
    archive (a previous run's survival-ordered population or Pareto front —
    ``NSGA2State.genomes`` rows are already sorted best-first by
    (rank, -crowding)); the remainder is drawn from the default random init
    so the restarted search keeps exploring. Used by the rolling-horizon
    router re-optimization to carry the front across workload windows.
    """
    archive = jnp.asarray(archive)
    assert archive.ndim == 2, "archive must be (A, D) genomes"
    n_seed = min(archive.shape[0], cfg.pop_size)

    def init_fn(key: jax.Array) -> jax.Array:
        if cfg.genome == "continuous":
            u = jax.random.uniform(key, (cfg.pop_size, cfg.n_genes))
            fresh = cfg.lo + u * (cfg.hi - cfg.lo)
            seeds = jnp.clip(archive[:n_seed].astype(fresh.dtype),
                             cfg.lo, cfg.hi)
        else:
            fresh = jax.random.randint(key, (cfg.pop_size, cfg.n_genes), 0,
                                       cfg.n_choices, dtype=jnp.int32)
            seeds = archive[:n_seed].astype(jnp.int32)
        return fresh.at[:n_seed].set(seeds)

    return init_fn


# ---------------------------------------------------------------------------
# Selection / survival
# ---------------------------------------------------------------------------

def binary_tournament(key: jax.Array, rank: jax.Array, crowd: jax.Array,
                      n: int) -> jax.Array:
    """Return (n,) winner indices of n independent binary tournaments."""
    P = rank.shape[0]
    idx = jax.random.randint(key, (n, 2), 0, P)
    a, b = idx[:, 0], idx[:, 1]
    a_better = (rank[a] < rank[b]) | ((rank[a] == rank[b]) & (crowd[a] > crowd[b]))
    return jnp.where(a_better, a, b)


def survival_select(F: jax.Array, P: int,
                    dominance_fn: Optional[Callable[[jax.Array], jax.Array]]
                    = None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Elitist (μ+λ) truncation: top-P of combined population by
    (rank asc, crowding desc). Returns (indices, rank_sel, crowd_sel).

    ``dominance_fn`` optionally computes the (2P, 2P) dominance matrix fed to
    the sort (e.g. the Pallas kernel); default is the jnp reference."""
    dom = dominance_fn(F) if dominance_fn is not None else None
    rank = non_dominated_sort(F, dom)
    crowd = crowding_distance(F, rank)
    # lexsort: primary rank asc, secondary crowd desc. Replace inf for sort
    # stability under -crowd (−inf sorts first which is what we want).
    neg_crowd = jnp.where(jnp.isinf(crowd), -jnp.inf, -crowd)
    order = jnp.lexsort((neg_crowd, rank))
    sel = order[:P]
    return sel, rank[sel], crowd[sel]


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class NSGA2:
    """Vectorized NSGA-II engine.

    Parameters
    ----------
    fitness_fn : FitnessFn
        Maps (genomes, key) -> (objectives (P, M), violation (P,)). Must be
        traceable (it is called under jit). Objectives are minimized.
    config : NSGA2Config
    init_fn : optional custom population initializer (key) -> (P, D) genomes.
        Defaults to uniform in bounds / uniform categorical. The paper's
        heuristic-biased init for direct genomes lives in core.fitness;
        warm-starting from a previous run's front uses :func:`archive_init`.
    use_pallas_dominance : compute the survival-selection dominance matrix
        with the Pallas kernel (``repro.kernels.dominance``) — native on TPU,
        interpreter mode elsewhere (CPU tests); semantics are identical to
        the jnp reference (parity-tested in tests/test_nsga2.py).
    """

    def __init__(self, fitness_fn: FitnessFn, config: NSGA2Config,
                 init_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
                 use_pallas_dominance: bool = False):
        self.fitness_fn = fitness_fn
        self.config = config
        self.init_fn = init_fn
        self.use_pallas_dominance = use_pallas_dominance
        self._dominance_fn = None
        if use_pallas_dominance:
            from ..kernels.dominance import dominance_matrix_pallas
            interpret = jax.default_backend() != "tpu"
            self._dominance_fn = lambda F: dominance_matrix_pallas(
                F, interpret=interpret).astype(bool)
        self._step = jax.jit(self._step_impl)

    # -- init ---------------------------------------------------------------
    def init(self, key: jax.Array) -> NSGA2State:
        cfg = self.config
        k_pop, k_fit, k_next = jax.random.split(key, 3)
        if self.init_fn is not None:
            genomes = self.init_fn(k_pop)
        elif cfg.genome == "continuous":
            D = cfg.lo.shape[0]
            u = jax.random.uniform(k_pop, (cfg.pop_size, D))
            genomes = cfg.lo + u * (cfg.hi - cfg.lo)
        else:
            if cfg.n_choices <= 0:
                raise ValueError("discrete genome requires init_fn or n_choices>0")
            genomes = jax.random.randint(
                k_pop, (cfg.pop_size, cfg.n_genes), 0, cfg.n_choices,
                dtype=jnp.int32)
        F_raw, violation = self.fitness_fn(genomes, k_fit)
        F = _penalize(F_raw, violation)
        dom = (self._dominance_fn(F) if self._dominance_fn is not None
               else None)
        rank = non_dominated_sort(F, dom)
        crowd = crowding_distance(F, rank)
        return NSGA2State(genomes, F, F_raw, violation, rank, crowd, k_next,
                          jnp.int32(0))

    # -- one generation -------------------------------------------------------
    def _step_impl(self, state: NSGA2State) -> NSGA2State:
        cfg = self.config
        P = cfg.pop_size
        key, k_sel, k_cx, k_mut, k_fit = jax.random.split(state.key, 5)

        parents = binary_tournament(k_sel, state.rank, state.crowd, P)
        pg = state.genomes[parents]
        p1, p2 = pg[0::2], pg[1::2]

        if cfg.genome == "continuous":
            c1, c2 = sbx_crossover(k_cx, p1, p2, cfg.lo, cfg.hi,
                                   cfg.crossover_prob, cfg.eta_crossover)
            offspring = jnp.concatenate([c1, c2], axis=0)
            offspring = polynomial_mutation(k_mut, offspring, cfg.lo, cfg.hi,
                                            cfg.mutation_prob, cfg.eta_mutation)
        else:
            c1, c2 = uniform_swap_crossover(k_cx, p1, p2, cfg.crossover_prob)
            offspring = jnp.concatenate([c1, c2], axis=0)
            offspring = reassignment_mutation(k_mut, offspring,
                                              cfg.mutation_prob, cfg.n_choices)

        F_off_raw, viol_off = self.fitness_fn(offspring, k_fit)
        F_off = _penalize(F_off_raw, viol_off)

        # (μ+λ) combine + survival
        genomes_all = jnp.concatenate([state.genomes, offspring], axis=0)
        F_all = jnp.concatenate([state.F, F_off], axis=0)
        F_raw_all = jnp.concatenate([state.F_raw, F_off_raw], axis=0)
        viol_all = jnp.concatenate([state.violation, viol_off], axis=0)
        sel, rank_sel, crowd_sel = survival_select(F_all, P,
                                                   self._dominance_fn)

        return NSGA2State(
            genomes=genomes_all[sel], F=F_all[sel], F_raw=F_raw_all[sel],
            violation=viol_all[sel], rank=rank_sel, crowd=crowd_sel, key=key,
            generation=state.generation + 1)

    # -- drivers --------------------------------------------------------------
    def evolve(self, key: jax.Array, n_generations: Optional[int] = None,
               callback: Optional[Callable[[NSGA2State], None]] = None
               ) -> NSGA2State:
        """Python-loop driver (allows host callbacks for logging)."""
        state = self.init(key)
        T = n_generations if n_generations is not None else self.config.n_generations
        for _ in range(T):
            state = self._step(state)
            if callback is not None:
                callback(state)
        return state

    @functools.partial(jax.jit, static_argnums=(0, 2))
    def evolve_scan(self, key: jax.Array, n_generations: int) -> NSGA2State:
        """Entire run as one lax.scan — used by the perf benchmark."""
        state = self.init(key)

        def body(s, _):
            return self._step_impl(s), None

        state, _ = jax.lax.scan(body, state, None, length=n_generations)
        return state

    # -- results --------------------------------------------------------------
    def pareto_front(self, state: NSGA2State) -> Tuple[jax.Array, jax.Array]:
        """Feasible rank-0 members: (genomes, raw objectives)."""
        mask = (state.rank == 0) & (state.violation <= 0)
        return state.genomes[mask], state.F_raw[mask]

    def select_by_weights(self, state: NSGA2State, weights: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
        """Pick one policy from the front by the paper's Eq. (1) weighted sum
        over min-max normalized objectives (ω1 RQ + ω2 C + ω3 RT)."""
        F = state.F_raw
        fmin = jnp.min(F, axis=0)
        fmax = jnp.max(F, axis=0)
        Fn = (F - fmin) / jnp.where(fmax - fmin <= 0, 1.0, fmax - fmin)
        score = Fn @ weights
        # mask non-front/infeasible
        bad = (state.rank != 0) | (state.violation > 0)
        score = jnp.where(bad, jnp.inf, score)
        i = jnp.argmin(score)
        return state.genomes[i], F[i]
