"""NSGA-II (Deb et al. 2002) fully vectorized in JAX.

This is the paper's §IV optimizer: a population of routing policies evolved
with non-dominated sorting + crowding distance, binary tournament selection,
crossover and mutation. Two genome encodings are supported, matching the two
policy representations in the paper:

* **continuous** (threshold genome, §IV-B.6): D decision variables in
  ``[lo, hi]`` — SBX crossover + polynomial mutation. This is what the runtime
  rule-based router consumes (θ_d,code, θ_d,math, θ_d,general, θ_q, θ_t,code,
  θ_t,math).
* **discrete** (direct assignment genome, §IV-B.1): one integer gene per
  request selecting a (node, model) pair — uniform-swap crossover ("swapping
  node-LLM pairs for a subset of requests") + random reassignment mutation.

The whole generation step is a single jitted function; ``evolve`` runs a
Python loop for logging, ``evolve_scan`` runs the entire optimization as one
``lax.scan`` (used by the perf benchmarks).

Constraints are handled with the standard constrained-domination trick folded
into a penalty: the fitness function may return a violation vector alongside
objectives; infeasible individuals get all objectives shifted by
``violation * PENALTY`` which makes every feasible point dominate them while
still ordering infeasible points by violation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .pareto import crowding_distance, non_dominated_sort

PENALTY = 1e6


class _StaticConfig(NamedTuple):
    """Hashable static slice of :class:`NSGA2Config` — the jit cache key of
    the module-level generation step. Two ``NSGA2`` instances with equal
    static configs (and the same fitness kernel) share one compiled
    executable; the continuous bounds stay *dynamic* arguments so re-fits
    with different bounds of the same shape also hit the cache."""

    pop_size: int
    crossover_prob: float
    mutation_prob: float
    eta_crossover: float
    eta_mutation: float
    genome: str
    n_choices: int
    n_genes: int


@dataclasses.dataclass(frozen=True)
class NSGA2Config:
    """Hyper-parameters (paper §V-A: P=100, T=100, pc=0.8, pm=0.1)."""

    pop_size: int = 100
    n_generations: int = 100
    crossover_prob: float = 0.8
    mutation_prob: float = 0.1
    eta_crossover: float = 15.0
    eta_mutation: float = 20.0
    genome: str = "continuous"  # "continuous" | "discrete"
    # continuous bounds (D,) arrays; discrete cardinality + genome length
    lo: Optional[jnp.ndarray] = None
    hi: Optional[jnp.ndarray] = None
    n_choices: int = 0
    # number of genes D for the default *discrete* init (e.g. n_requests for
    # direct-assignment genomes); continuous genomes take D from lo/hi
    genome_length: int = 0

    def __post_init__(self):
        assert self.pop_size % 2 == 0, "pop_size must be even"
        assert self.genome in ("continuous", "discrete")

    @classmethod
    def from_policy(cls, policy, **overrides) -> "NSGA2Config":
        """Derive the genome encoding from a registered RoutingPolicy.

        ``policy`` is a registry name or RoutingPolicy object. Continuous
        policies contribute their search bounds (D = GenomeSpec.length, so
        genome-length defaults cannot drift from the decision rule);
        discrete per-request policies ("direct") set ``genome="discrete"``
        and require the caller to pass trace-dependent ``genome_length`` /
        ``n_choices`` via ``overrides``. Other NSGA-II hyper-parameters
        (pop_size, n_generations, ...) pass through ``overrides``.
        """
        from .policies import get_policy
        pol = get_policy(policy) if isinstance(policy, str) else policy
        spec = pol.genome_spec
        if spec.discrete:
            overrides.setdefault("genome", "discrete")
            return cls(**overrides)
        overrides.setdefault("genome", "continuous")
        overrides.setdefault("lo", jnp.asarray(spec.lo))
        overrides.setdefault("hi", jnp.asarray(spec.hi))
        return cls(**overrides)

    @property
    def n_genes(self) -> int:
        """Genome dimensionality D implied by the config."""
        if self.genome == "continuous":
            assert self.lo is not None, "continuous genome requires bounds"
            return int(self.lo.shape[0])
        assert self.genome_length > 0, \
            "discrete genome requires genome_length (or a custom init_fn)"
        return self.genome_length

    @property
    def static_key(self) -> _StaticConfig:
        """Static (hashable) part of the config; D = -1 when only a custom
        ``init_fn`` can determine the genome length."""
        if self.genome == "continuous" and self.lo is not None:
            D = int(self.lo.shape[0])
        elif self.genome == "discrete" and self.genome_length > 0:
            D = self.genome_length
        else:
            D = -1
        return _StaticConfig(
            pop_size=self.pop_size, crossover_prob=self.crossover_prob,
            mutation_prob=self.mutation_prob,
            eta_crossover=self.eta_crossover,
            eta_mutation=self.eta_mutation, genome=self.genome,
            n_choices=self.n_choices, n_genes=D)


class NSGA2State(NamedTuple):
    genomes: jax.Array     # (P, D) float32 or int32
    F: jax.Array           # (P, M) penalized objectives
    F_raw: jax.Array       # (P, M) unpenalized objectives
    violation: jax.Array   # (P,)
    rank: jax.Array        # (P,)
    crowd: jax.Array       # (P,)
    key: jax.Array
    generation: jax.Array  # scalar int32


# FitnessFn: (genomes (P, D), key) -> (F (P, M), violation (P,))
FitnessFn = Callable[[jax.Array, jax.Array], Tuple[jax.Array, jax.Array]]


def _penalize(F: jax.Array, violation: jax.Array) -> jax.Array:
    return F + (violation[:, None] > 0) * (PENALTY + violation[:, None] * PENALTY)


# ---------------------------------------------------------------------------
# Variation operators
# ---------------------------------------------------------------------------

def sbx_crossover(key: jax.Array, p1: jax.Array, p2: jax.Array,
                  lo: jax.Array, hi: jax.Array, pc: float, eta: float
                  ) -> Tuple[jax.Array, jax.Array]:
    """Simulated binary crossover on (n_pairs, D) parent blocks."""
    k_pair, k_gene, k_u = jax.random.split(key, 3)
    n_pairs, D = p1.shape
    u = jax.random.uniform(k_u, (n_pairs, D))
    beta = jnp.where(
        u <= 0.5,
        (2.0 * u) ** (1.0 / (eta + 1.0)),
        (1.0 / (2.0 * (1.0 - u))) ** (1.0 / (eta + 1.0)),
    )
    c1 = 0.5 * ((1.0 + beta) * p1 + (1.0 - beta) * p2)
    c2 = 0.5 * ((1.0 - beta) * p1 + (1.0 + beta) * p2)
    # per-gene 0.5 exchange, per-pair pc gate
    do_pair = jax.random.uniform(k_pair, (n_pairs, 1)) < pc
    do_gene = jax.random.uniform(k_gene, (n_pairs, D)) < 0.5
    apply = do_pair & do_gene
    c1 = jnp.where(apply, c1, p1)
    c2 = jnp.where(apply, c2, p2)
    return jnp.clip(c1, lo, hi), jnp.clip(c2, lo, hi)


def polynomial_mutation(key: jax.Array, x: jax.Array, lo: jax.Array,
                        hi: jax.Array, pm: float, eta: float) -> jax.Array:
    """Polynomial mutation on (P, D)."""
    k_gate, k_u = jax.random.split(key)
    u = jax.random.uniform(k_u, x.shape)
    delta = jnp.where(
        u < 0.5,
        (2.0 * u) ** (1.0 / (eta + 1.0)) - 1.0,
        1.0 - (2.0 * (1.0 - u)) ** (1.0 / (eta + 1.0)),
    )
    mutated = x + delta * (hi - lo)
    gate = jax.random.uniform(k_gate, x.shape) < pm
    return jnp.clip(jnp.where(gate, mutated, x), lo, hi)


def uniform_swap_crossover(key: jax.Array, p1: jax.Array, p2: jax.Array,
                           pc: float) -> Tuple[jax.Array, jax.Array]:
    """Paper §IV-B.4: swap node-LLM pairs for a subset of requests."""
    k_pair, k_gene = jax.random.split(key)
    n_pairs, D = p1.shape
    do_pair = jax.random.uniform(k_pair, (n_pairs, 1)) < pc
    swap = (jax.random.uniform(k_gene, (n_pairs, D)) < 0.5) & do_pair
    c1 = jnp.where(swap, p2, p1)
    c2 = jnp.where(swap, p1, p2)
    return c1, c2


def reassignment_mutation(key: jax.Array, x: jax.Array, pm: float,
                          n_choices: int) -> jax.Array:
    """Paper §IV-B.4: reassign a small fraction of requests to other pairs."""
    k_gate, k_new = jax.random.split(key)
    gate = jax.random.uniform(k_gate, x.shape) < pm
    fresh = jax.random.randint(k_new, x.shape, 0, n_choices, dtype=x.dtype)
    return jnp.where(gate, fresh, x)


# ---------------------------------------------------------------------------
# Warm start
# ---------------------------------------------------------------------------

def archive_init(archive: jax.Array, cfg: NSGA2Config
                 ) -> Callable[[jax.Array], jax.Array]:
    """``init_fn`` seeding a population from an elite archive (warm start).

    The first ``min(len(archive), pop_size)`` individuals are copied from the
    archive (a previous run's survival-ordered population or Pareto front —
    ``NSGA2State.genomes`` rows are already sorted best-first by
    (rank, -crowding)); the remainder is drawn from the default random init
    so the restarted search keeps exploring. Used by the rolling-horizon
    router re-optimization to carry the front across workload windows.
    """
    archive = jnp.asarray(archive)
    assert archive.ndim == 2, "archive must be (A, D) genomes"
    n_seed = min(archive.shape[0], cfg.pop_size)

    def init_fn(key: jax.Array) -> jax.Array:
        if cfg.genome == "continuous":
            u = jax.random.uniform(key, (cfg.pop_size, cfg.n_genes))
            fresh = cfg.lo + u * (cfg.hi - cfg.lo)
            seeds = jnp.clip(archive[:n_seed].astype(fresh.dtype),
                             cfg.lo, cfg.hi)
        else:
            fresh = jax.random.randint(key, (cfg.pop_size, cfg.n_genes), 0,
                                       cfg.n_choices, dtype=jnp.int32)
            seeds = archive[:n_seed].astype(jnp.int32)
        return fresh.at[:n_seed].set(seeds)

    return init_fn


# ---------------------------------------------------------------------------
# Selection / survival
# ---------------------------------------------------------------------------

def binary_tournament(key: jax.Array, rank: jax.Array, crowd: jax.Array,
                      n: int) -> jax.Array:
    """Return (n,) winner indices of n independent binary tournaments."""
    P = rank.shape[0]
    idx = jax.random.randint(key, (n, 2), 0, P)
    a, b = idx[:, 0], idx[:, 1]
    a_better = (rank[a] < rank[b]) | ((rank[a] == rank[b]) & (crowd[a] > crowd[b]))
    return jnp.where(a_better, a, b)


def survival_select(F: jax.Array, P: int,
                    dominance_fn: Optional[Callable[[jax.Array], jax.Array]]
                    = None, top: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Elitist (μ+λ) truncation: top-P of combined population by
    (rank asc, crowding desc). Returns (indices, rank_sel, crowd_sel).

    ``dominance_fn`` optionally computes the (2P, 2P) dominance matrix fed to
    the sort (e.g. the Pallas kernel); default is the jnp reference.
    ``top`` forwards the early-exit quota to ``non_dominated_sort`` —
    survival only needs ranks up to the front containing the P-th survivor,
    so the generation step passes ``top=P`` (ranks of selected individuals
    are identical to the full sort; unpeeled tails share a sentinel rank and
    are never selected)."""
    dom = dominance_fn(F) if dominance_fn is not None else None
    rank = non_dominated_sort(F, dom, top=top)
    crowd = crowding_distance(F, rank)
    # lexsort: primary rank asc, secondary crowd desc. Replace inf for sort
    # stability under -crowd (−inf sorts first which is what we want).
    neg_crowd = jnp.where(jnp.isinf(crowd), -jnp.inf, -crowd)
    order = jnp.lexsort((neg_crowd, rank))
    sel = order[:P]
    return sel, rank[sel], crowd[sel]


# ---------------------------------------------------------------------------
# Module-level jitted generation step / full run
#
# Historically every ``NSGA2`` instance re-jitted its own generation step
# (``jax.jit(self._step_impl)``), so the rolling-horizon router paid a full
# retrace per re-fit even with identical hyper-parameters and table shapes.
# The step now lives here, keyed on (static config, fitness kernel identity,
# dominance backend): any two instances with equal statics share one
# compiled executable, and per-evaluator data (tables, bounds, archives)
# flows through dynamic arguments. ``core.fitness.make_fitness`` returns
# fitness callables carrying a memoized ``.kernel``/``.data`` split exactly
# so this cache hits across evaluators.
# ---------------------------------------------------------------------------


def _call_fitness(fitness_fn, genomes, key, data):
    """Invoke a fitness function in either calling convention: legacy
    ``(genomes, key)`` closures, or cache-friendly ``(genomes, key, data)``
    kernels whose per-evaluator state arrives as a dynamic pytree."""
    if data is None:
        return fitness_fn(genomes, key)
    return fitness_fn(genomes, key, data)


def _dominance_matrix_for(F, dominance: str):
    """Resolve the survival dominance matrix backend ("jnp" -> None, i.e.
    the reference inside non_dominated_sort; "pallas" -> the kernel,
    interpret-mode off TPU)."""
    if dominance == "jnp":
        return None
    from ..kernels.dominance import dominance_matrix_pallas
    interpret = jax.default_backend() != "tpu"
    return dominance_matrix_pallas(F, interpret=interpret).astype(bool)


def _init_core(key, lo, hi, archive, fitness_data, scfg: _StaticConfig,
               fitness_fn, dominance: str, init_fn) -> NSGA2State:
    k_pop, k_fit, k_next = jax.random.split(key, 3)
    if init_fn is not None:
        genomes = init_fn(k_pop)
    elif scfg.genome == "continuous":
        assert scfg.n_genes > 0, "continuous genome requires bounds"
        u = jax.random.uniform(k_pop, (scfg.pop_size, scfg.n_genes))
        genomes = lo + u * (hi - lo)
    else:
        if scfg.n_choices <= 0:
            raise ValueError("discrete genome requires init_fn or n_choices>0")
        assert scfg.n_genes > 0, \
            "discrete genome requires genome_length (or a custom init_fn)"
        genomes = jax.random.randint(
            k_pop, (scfg.pop_size, scfg.n_genes), 0, scfg.n_choices,
            dtype=jnp.int32)
    if archive is not None:
        # warm start (same semantics as archive_init, but the archive is a
        # *dynamic* argument so repeated warm-started re-fits share a trace)
        n_seed = min(archive.shape[0], scfg.pop_size)
        if scfg.genome == "continuous":
            seeds = jnp.clip(archive[:n_seed].astype(genomes.dtype), lo, hi)
        else:
            seeds = archive[:n_seed].astype(jnp.int32)
        genomes = genomes.at[:n_seed].set(seeds)
    F_raw, violation = _call_fitness(fitness_fn, genomes, k_fit, fitness_data)
    F = _penalize(F_raw, violation)
    rank = non_dominated_sort(F, _dominance_matrix_for(F, dominance))
    crowd = crowding_distance(F, rank)
    return NSGA2State(genomes, F, F_raw, violation, rank, crowd, k_next,
                      jnp.int32(0))


def _step_core(state: NSGA2State, lo, hi, fitness_data,
               scfg: _StaticConfig, fitness_fn, dominance: str) -> NSGA2State:
    P = scfg.pop_size
    key, k_sel, k_cx, k_mut, k_fit = jax.random.split(state.key, 5)

    parents = binary_tournament(k_sel, state.rank, state.crowd, P)
    pg = state.genomes[parents]
    p1, p2 = pg[0::2], pg[1::2]

    if scfg.genome == "continuous":
        c1, c2 = sbx_crossover(k_cx, p1, p2, lo, hi,
                               scfg.crossover_prob, scfg.eta_crossover)
        offspring = jnp.concatenate([c1, c2], axis=0)
        offspring = polynomial_mutation(k_mut, offspring, lo, hi,
                                        scfg.mutation_prob,
                                        scfg.eta_mutation)
    else:
        c1, c2 = uniform_swap_crossover(k_cx, p1, p2, scfg.crossover_prob)
        offspring = jnp.concatenate([c1, c2], axis=0)
        offspring = reassignment_mutation(k_mut, offspring,
                                          scfg.mutation_prob, scfg.n_choices)

    F_off_raw, viol_off = _call_fitness(fitness_fn, offspring, k_fit,
                                        fitness_data)
    F_off = _penalize(F_off_raw, viol_off)

    # (μ+λ) combine + survival (ranks beyond the top-P cutoff early-exit)
    genomes_all = jnp.concatenate([state.genomes, offspring], axis=0)
    F_all = jnp.concatenate([state.F, F_off], axis=0)
    F_raw_all = jnp.concatenate([state.F_raw, F_off_raw], axis=0)
    viol_all = jnp.concatenate([state.violation, viol_off], axis=0)
    dom_fn = (None if dominance == "jnp"
              else lambda F: _dominance_matrix_for(F, dominance))
    sel, rank_sel, crowd_sel = survival_select(F_all, P, dom_fn, top=P)

    return NSGA2State(
        genomes=genomes_all[sel], F=F_all[sel], F_raw=F_raw_all[sel],
        violation=viol_all[sel], rank=rank_sel, crowd=crowd_sel, key=key,
        generation=state.generation + 1)


@functools.partial(jax.jit,
                   static_argnames=("scfg", "fitness_fn", "dominance"))
def _nsga2_step(state: NSGA2State, lo, hi, fitness_data, *,
                scfg: _StaticConfig, fitness_fn, dominance: str
                ) -> NSGA2State:
    return _step_core(state, lo, hi, fitness_data, scfg, fitness_fn,
                      dominance)


@functools.partial(jax.jit,
                   static_argnames=("scfg", "fitness_fn", "dominance",
                                    "n_generations", "init_fn"))
def _nsga2_run(key, lo, hi, archive, fitness_data, *, scfg: _StaticConfig,
               fitness_fn, dominance: str, n_generations: int,
               init_fn=None) -> NSGA2State:
    """Entire optimization (init + T generations) as one compiled program."""
    state = _init_core(key, lo, hi, archive, fitness_data, scfg, fitness_fn,
                       dominance, init_fn)

    def body(s, _):
        return _step_core(s, lo, hi, fitness_data, scfg, fitness_fn,
                          dominance), None

    state, _ = jax.lax.scan(body, state, None, length=n_generations)
    return state


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class NSGA2:
    """Vectorized NSGA-II engine.

    Parameters
    ----------
    fitness_fn : FitnessFn
        Maps (genomes, key) -> (objectives (P, M), violation (P,)). Must be
        traceable (it is called under jit). Objectives are minimized.
    config : NSGA2Config
    init_fn : optional custom population initializer (key) -> (P, D) genomes.
        Defaults to uniform in bounds / uniform categorical. The paper's
        heuristic-biased init for direct genomes lives in core.fitness;
        warm-starting from a previous run's front prefers
        ``evolve_scan(..., archive=)`` (a dynamic argument, so repeated
        warm-started re-fits share one compiled executable) over the legacy
        :func:`archive_init` closure.
    use_pallas_dominance : compute the survival-selection dominance matrix
        with the Pallas kernel (``repro.kernels.dominance``) — native on TPU,
        interpreter mode elsewhere (CPU tests); semantics are identical to
        the jnp reference (parity-tested in tests/test_nsga2.py).
    """

    def __init__(self, fitness_fn: FitnessFn, config: NSGA2Config,
                 init_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
                 use_pallas_dominance: bool = False):
        self.fitness_fn = fitness_fn
        self.config = config
        self.init_fn = init_fn
        self.use_pallas_dominance = use_pallas_dominance
        self._dominance = "pallas" if use_pallas_dominance else "jnp"
        # cache-friendly split: fitness callables built by
        # core.fitness.make_fitness carry a memoized module-level `.kernel`
        # plus a `.data` pytree — the kernel identity is the jit cache key,
        # the data (tables, arrays) stays dynamic, so two optimizers over
        # two same-shaped evaluators share one compiled step. Legacy
        # closures run as-is (static identity -> one trace per closure);
        # the module-level jit cache then retains the closure and whatever
        # it captures for the process lifetime, so long-lived callers
        # creating many NSGA2 instances should prefer make_fitness kernels
        # or call jax.clear_caches() periodically.
        kernel = getattr(fitness_fn, "kernel", None)
        if kernel is not None:
            self._fitness_fn = kernel
            self._fitness_data = fitness_fn.data
        else:
            self._fitness_fn = fitness_fn
            self._fitness_data = None
        if config.genome == "continuous" and config.lo is not None:
            self._lo = jnp.asarray(config.lo)
            self._hi = jnp.asarray(config.hi)
        else:
            self._lo = jnp.zeros((0,), jnp.float32)
            self._hi = jnp.zeros((0,), jnp.float32)

    # -- init ---------------------------------------------------------------
    def init(self, key: jax.Array) -> NSGA2State:
        return _init_core(key, self._lo, self._hi, None, self._fitness_data,
                          self.config.static_key, self._fitness_fn,
                          self._dominance, self.init_fn)

    # -- one generation -------------------------------------------------------
    def _step(self, state: NSGA2State) -> NSGA2State:
        return _nsga2_step(state, self._lo, self._hi, self._fitness_data,
                           scfg=self.config.static_key,
                           fitness_fn=self._fitness_fn,
                           dominance=self._dominance)

    # -- drivers --------------------------------------------------------------
    def evolve(self, key: jax.Array, n_generations: Optional[int] = None,
               callback: Optional[Callable[[NSGA2State], None]] = None
               ) -> NSGA2State:
        """Python-loop driver (allows host callbacks for logging)."""
        state = self.init(key)
        T = n_generations if n_generations is not None else self.config.n_generations
        for _ in range(T):
            state = self._step(state)
            if callback is not None:
                callback(state)
        return state

    def evolve_scan(self, key: jax.Array,
                    n_generations: Optional[int] = None,
                    archive: Optional[jax.Array] = None) -> NSGA2State:
        """Entire run as one lax.scan in one compiled program.

        ``archive`` optionally warm-starts the population from a previous
        run's survival-ordered genomes (same semantics as
        :func:`archive_init`, but passed as a *dynamic* argument so repeated
        warm-started re-fits reuse the compiled executable instead of
        retracing per closure identity)."""
        T = (n_generations if n_generations is not None
             else self.config.n_generations)
        if (self.init_fn is None and self.config.genome == "discrete"
                and self.config.n_choices <= 0):
            raise ValueError("discrete genome requires init_fn or n_choices>0")
        arch = None if archive is None else jnp.asarray(archive)
        return _nsga2_run(key, self._lo, self._hi, arch, self._fitness_data,
                          scfg=self.config.static_key,
                          fitness_fn=self._fitness_fn,
                          dominance=self._dominance, n_generations=T,
                          init_fn=self.init_fn)

    # -- results --------------------------------------------------------------
    def pareto_front(self, state: NSGA2State) -> Tuple[jax.Array, jax.Array]:
        """Feasible rank-0 members: (genomes, raw objectives)."""
        mask = (state.rank == 0) & (state.violation <= 0)
        return state.genomes[mask], state.F_raw[mask]

    def select_by_weights(self, state: NSGA2State, weights: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
        """Pick one policy from the front by the paper's Eq. (1) weighted sum
        over min-max normalized objectives (ω1 RQ + ω2 C + ω3 RT)."""
        F = state.F_raw
        fmin = jnp.min(F, axis=0)
        fmax = jnp.max(F, axis=0)
        Fn = (F - fmin) / jnp.where(fmax - fmin <= 0, 1.0, fmax - fmin)
        score = Fn @ weights
        # mask non-front/infeasible
        bad = (state.rank != 0) | (state.violation > 0)
        score = jnp.where(bad, jnp.inf, score)
        i = jnp.argmin(score)
        return state.genomes[i], F[i]
