"""Objective metrics (paper Eqs. 2–5), the §V-D composite score, and the
beyond-paper QoE/SLO objectives.

All metrics are computed from per-request vectors produced by the evaluator:
``q`` (quality score in [0,1]), ``cost`` ($ per request), ``rt`` (seconds).

The QoE extension splits ``rt`` into its serving phases — ``ttft`` (time to
first token: upload + queue wait + prefill) and ``tpot`` (decode seconds per
output token) — and scores a policy by **SLO attainment**: the fraction of
requests meeting both of their per-request deadlines (see
``repro.workload.slo``). ``aggregate_qoe`` packs the violation rate as a
fourth minimized objective so the NSGA-II searches the (quality, cost,
latency, attainment) space directly.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np


class Objectives(NamedTuple):
    RQ: jnp.ndarray   # Eq. 2: mean(1 - q)  (minimize)
    C: jnp.ndarray    # Eq. 3: mean cost    (minimize)
    RT: jnp.ndarray   # Eq. 4: mean latency (minimize)

    def stack(self) -> jnp.ndarray:
        return jnp.stack([self.RQ, self.C, self.RT])


def aggregate(q: jnp.ndarray, cost: jnp.ndarray, rt: jnp.ndarray) -> Objectives:
    return Objectives(RQ=jnp.mean(1.0 - q), C=jnp.mean(cost), RT=jnp.mean(rt))


def weighted_scalar(obj: Objectives, weights: Sequence[float],
                    norm_lo: Sequence[float], norm_hi: Sequence[float]
                    ) -> jnp.ndarray:
    """Paper Eq. (1): min ω1·RQ + ω2·C + ω3·RT over min-max normalized terms."""
    f = obj.stack()
    lo = jnp.asarray(norm_lo)
    hi = jnp.asarray(norm_hi)
    fn = (f - lo) / jnp.where(hi - lo <= 0, 1.0, hi - lo)
    return jnp.dot(jnp.asarray(weights), fn)


class QoEObjectives(NamedTuple):
    """Paper objectives + SLO violation rate (all minimized)."""

    RQ: jnp.ndarray   # Eq. 2: mean(1 - q)
    C: jnp.ndarray    # Eq. 3: mean cost
    RT: jnp.ndarray   # Eq. 4: mean latency
    V: jnp.ndarray    # 1 - SLO attainment (fraction missing a deadline)

    def stack(self) -> jnp.ndarray:
        return jnp.stack([self.RQ, self.C, self.RT, self.V])


def slo_ok(ttft: jnp.ndarray, tpot: jnp.ndarray,
           ttft_deadline: jnp.ndarray, tpot_deadline: jnp.ndarray
           ) -> jnp.ndarray:
    """(I,) bool — request met BOTH phase deadlines."""
    return (ttft <= ttft_deadline) & (tpot <= tpot_deadline)


def slo_attainment(ttft: jnp.ndarray, tpot: jnp.ndarray,
                   ttft_deadline: jnp.ndarray, tpot_deadline: jnp.ndarray
                   ) -> jnp.ndarray:
    """Fraction of requests meeting both TTFT and TPOT deadlines."""
    return jnp.mean(slo_ok(ttft, tpot, ttft_deadline, tpot_deadline)
                    .astype(jnp.float32))


def aggregate_qoe(q: jnp.ndarray, cost: jnp.ndarray, rt: jnp.ndarray,
                  ttft: jnp.ndarray, tpot: jnp.ndarray,
                  ttft_deadline: jnp.ndarray, tpot_deadline: jnp.ndarray
                  ) -> QoEObjectives:
    att = slo_attainment(ttft, tpot, ttft_deadline, tpot_deadline)
    return QoEObjectives(RQ=jnp.mean(1.0 - q), C=jnp.mean(cost),
                         RT=jnp.mean(rt), V=1.0 - att)


def overall_scores(avg_quality: np.ndarray, avg_rt: np.ndarray,
                   avg_cost: np.ndarray) -> np.ndarray:
    """§V-D composite: min-max normalize each dimension across the compared
    strategies (larger = better), then average the three normalized scores."""
    q, t, c = map(np.asarray, (avg_quality, avg_rt, avg_cost))

    def _norm(x, larger_better):
        rng = x.max() - x.min()
        if rng <= 0:
            return np.ones_like(x)
        return (x - x.min()) / rng if larger_better else (x.max() - x) / rng

    return (_norm(q, True) + _norm(t, False) + _norm(c, False)) / 3.0
