"""Objective metrics (paper Eqs. 2–5) and the §V-D composite score.

All metrics are computed from per-request vectors produced by the evaluator:
``q`` (quality score in [0,1]), ``cost`` ($ per request), ``rt`` (seconds).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np


class Objectives(NamedTuple):
    RQ: jnp.ndarray   # Eq. 2: mean(1 - q)  (minimize)
    C: jnp.ndarray    # Eq. 3: mean cost    (minimize)
    RT: jnp.ndarray   # Eq. 4: mean latency (minimize)

    def stack(self) -> jnp.ndarray:
        return jnp.stack([self.RQ, self.C, self.RT])


def aggregate(q: jnp.ndarray, cost: jnp.ndarray, rt: jnp.ndarray) -> Objectives:
    return Objectives(RQ=jnp.mean(1.0 - q), C=jnp.mean(cost), RT=jnp.mean(rt))


def weighted_scalar(obj: Objectives, weights: Sequence[float],
                    norm_lo: Sequence[float], norm_hi: Sequence[float]
                    ) -> jnp.ndarray:
    """Paper Eq. (1): min ω1·RQ + ω2·C + ω3·RT over min-max normalized terms."""
    f = obj.stack()
    lo = jnp.asarray(norm_lo)
    hi = jnp.asarray(norm_hi)
    fn = (f - lo) / jnp.where(hi - lo <= 0, 1.0, hi - lo)
    return jnp.dot(jnp.asarray(weights), fn)


def overall_scores(avg_quality: np.ndarray, avg_rt: np.ndarray,
                   avg_cost: np.ndarray) -> np.ndarray:
    """§V-D composite: min-max normalize each dimension across the compared
    strategies (larger = better), then average the three normalized scores."""
    q, t, c = map(np.asarray, (avg_quality, avg_rt, avg_cost))

    def _norm(x, larger_better):
        rng = x.max() - x.min()
        if rng <= 0:
            return np.ones_like(x)
        return (x - x.min()) / rng if larger_better else (x.max() - x) / rng

    return (_norm(q, True) + _norm(t, False) + _norm(c, False)) / 3.0
