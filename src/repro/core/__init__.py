"""The paper's contribution: NSGA-II multi-objective LLM request routing."""
from .nsga2 import NSGA2, NSGA2Config, NSGA2State
from .objectives import Objectives, aggregate, overall_scores
from .pareto import (crowding_distance, dominance_matrix, hypervolume_2d,
                     hypervolume_mc, non_dominated_sort, pareto_mask)
from .policies import (GenomeSpec, PolicyInputs, RoutingPolicy, get_policy,
                       list_policies, register_policy, runtime_policies)
from .policy import (BOUNDS_HI, BOUNDS_LO, PAPER_DEFAULTS, THRESHOLD_NAMES,
                     decide_pair_jnp, decide_pair_py)

__all__ = [
    "NSGA2", "NSGA2Config", "NSGA2State", "Objectives", "aggregate",
    "overall_scores", "crowding_distance", "dominance_matrix",
    "hypervolume_2d", "hypervolume_mc", "non_dominated_sort", "pareto_mask",
    "decide_pair_jnp", "decide_pair_py", "THRESHOLD_NAMES", "BOUNDS_LO",
    "BOUNDS_HI", "PAPER_DEFAULTS",
    "GenomeSpec", "PolicyInputs", "RoutingPolicy", "register_policy",
    "get_policy", "list_policies", "runtime_policies",
]
