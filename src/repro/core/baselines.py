"""Baseline routing strategies (paper §V-C).

Each baseline produces an assignment vector (I,) of pair indices consumed by
the same TraceEvaluator as the NSGA-II policies, so the comparison is
apples-to-apples:

* **Cloud Only** — everything to gemma3:27b on the cloud node.
* **Edge Only** — to an edge model chosen by request type, round-robin over
  edge nodes.
* **Random Router** — uniform over all (node, model) pairs.
* **Round Robin Router** — cycles cloud and edge nodes evenly; model selected
  by request type on edge, the hosted model on cloud.
"""
from __future__ import annotations

import numpy as np

from ..cluster.spec import ClusterArrays, ClusterSpec, MODEL_TYPE_INDEX
from ..workload.trace import Trace

# dataset id (mbpp, gsm8k, squad, hellaswag) -> preferred edge model type
_TASK_TO_TYPE = np.array([MODEL_TYPE_INDEX["coder"], MODEL_TYPE_INDEX["math"],
                          MODEL_TYPE_INDEX["instruct"],
                          MODEL_TYPE_INDEX["instruct"]], np.int32)


def _edge_pair_for(arrays: ClusterArrays, model_type: int, node_slot: int) -> int:
    row = np.asarray(arrays.edge_pairs_by_type[model_type])
    row = row[row >= 0]
    assert row.size, f"no edge pair of type {model_type}"
    return int(row[node_slot % row.size])


def cloud_only(trace: Trace, cluster: ClusterSpec) -> np.ndarray:
    arrays = cluster.to_arrays()
    return np.full(trace.n_requests, int(arrays.cloud_fallback_pair), np.int32)


def edge_only(trace: Trace, cluster: ClusterSpec) -> np.ndarray:
    arrays = cluster.to_arrays()
    out = np.zeros(trace.n_requests, np.int32)
    for i in range(trace.n_requests):
        mt = int(_TASK_TO_TYPE[trace.task[i]])
        out[i] = _edge_pair_for(arrays, mt, i)  # round-robin over edge nodes
    return out


def random_router(trace: Trace, cluster: ClusterSpec, seed: int = 0) -> np.ndarray:
    """Uniform tier (cloud/edge) choice, then uniform pair within the tier.

    Note: Table II's Random-Router cost (5.71e-5 $) and RT (2.36 s) sit almost
    exactly halfway between Cloud-Only and Edge-Only, which implies the
    paper's implementation drew the *tier* uniformly (≈50% cloud share) rather
    than sampling the 10 (node, model) pairs uniformly (which would give a 10%
    cloud share and ≈2.7e-5 $). We match the published behaviour.
    """
    arrays = cluster.to_arrays()
    rng = np.random.default_rng(seed)
    is_edge = np.asarray(arrays.pair_is_edge)
    edge_pairs = np.where(is_edge)[0]
    cloud_pairs = np.where(~is_edge)[0]
    to_cloud = rng.random(trace.n_requests) < 0.5
    out = np.where(to_cloud,
                   rng.choice(cloud_pairs, size=trace.n_requests),
                   rng.choice(edge_pairs, size=trace.n_requests))
    return out.astype(np.int32)


def round_robin(trace: Trace, cluster: ClusterSpec) -> np.ndarray:
    """Alternate cloud <-> (next edge node); model by request type on edge.

    "Requests are evenly routed to cloud and edge nodes in a cyclic manner" —
    the published RT (2.4971 s ≈ the exact midpoint of Cloud-Only and
    Edge-Only) confirms a 50/50 cloud/edge split, i.e. the cycle alternates
    between the cloud node and the next edge node, not across the 4 nodes
    uniformly.
    """
    arrays = cluster.to_arrays()
    node_is_edge = np.asarray(arrays.node_is_edge)
    pair_node = np.asarray(arrays.pair_node)
    pair_type = np.asarray(arrays.pair_model_type)
    edge_nodes = np.where(node_is_edge)[0]
    cloud_nodes = np.where(~node_is_edge)[0]
    out = np.zeros(trace.n_requests, np.int32)
    e = c = 0
    for i in range(trace.n_requests):
        # flip parity every dataset cycle (period 4) so the 2-cycle here does
        # not systematically pin specific datasets to one tier
        cloud_turn = ((i % 2) ^ ((i // 4) % 2)) == 0
        if cloud_turn:  # cloud turn
            node = int(cloud_nodes[c % cloud_nodes.size])
            c += 1
            cands = np.where(pair_node == node)[0]
            out[i] = int(cands[0])
        else:            # edge turn
            node = int(edge_nodes[e % edge_nodes.size])
            e += 1
            mt = int(_TASK_TO_TYPE[trace.task[i]])
            cands = np.where((pair_node == node) & (pair_type == mt))[0]
            if cands.size == 0:  # node lacks the type: any model it hosts
                cands = np.where(pair_node == node)[0]
            out[i] = int(cands[0])
    return out


def heuristic_bias_init(trace: Trace, cluster: ClusterSpec, pop_size: int,
                        seed: int = 0) -> np.ndarray:
    """Paper §IV-B.1 initial population for the *direct* genome: random with a
    heuristic bias — lightweight requests toward edge, complex toward cloud."""
    arrays = cluster.to_arrays()
    rng = np.random.default_rng(seed)
    I = trace.n_requests
    edge_pairs = np.where(np.asarray(arrays.pair_is_edge))[0]
    cloud_pairs = np.where(~np.asarray(arrays.pair_is_edge))[0]
    pop = np.zeros((pop_size, I), np.int32)
    p_edge = np.clip(1.0 - trace.complexity, 0.05, 0.95)  # light -> edge
    for p in range(pop_size):
        to_edge = rng.random(I) < p_edge
        pop[p] = np.where(to_edge,
                          rng.choice(edge_pairs, size=I),
                          rng.choice(cloud_pairs, size=I))
    return pop
