"""Pareto utilities for NSGA-II, fully vectorized in JAX.

All functions operate on an objective matrix ``F`` of shape (P, M) where P is
the population size and M the number of objectives, **minimization** convention
throughout (the paper minimizes RQ, C, RT — Eq. (1)).

These are the jit-friendly building blocks used by :mod:`repro.core.nsga2`;
:mod:`repro.kernels.dominance` provides a Pallas TPU kernel for the dominance
matrix with identical semantics (tested against :func:`dominance_matrix`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "dominance_matrix",
    "non_dominated_sort",
    "crowding_distance",
    "pareto_mask",
    "hypervolume_2d",
    "hypervolume_mc",
]


def dominance_matrix(F: jax.Array) -> jax.Array:
    """Boolean (P, P) matrix D with D[i, j] = True iff i dominates j.

    i dominates j when i is <= j in every objective and < in at least one.
    """
    # (P, 1, M) vs (1, P, M)
    le = jnp.all(F[:, None, :] <= F[None, :, :], axis=-1)
    lt = jnp.any(F[:, None, :] < F[None, :, :], axis=-1)
    return le & lt


def pareto_mask(F: jax.Array) -> jax.Array:
    """(P,) bool mask of non-dominated rows of F."""
    dom = dominance_matrix(F)
    return ~jnp.any(dom, axis=0)


def non_dominated_sort(F: jax.Array, dom: jax.Array | None = None,
                       top: int | None = None) -> jax.Array:
    """Return (P,) int32 front ranks (0 = best / non-dominated front).

    Iterative front peeling: repeatedly take the set of individuals with no
    remaining dominator, assign them the current rank, remove them. Runs a
    fixed P-iteration ``lax.while_loop`` upper bound (each iteration peels at
    least one individual) so it stays jittable with static shapes.

    ``dom`` optionally supplies a precomputed (P, P) bool dominance matrix —
    the Pallas kernel in :mod:`repro.kernels.dominance` produces one without
    the O(P²·M) broadcast materializing in HBM on TPU.

    ``top`` enables the survival-selection early exit: peeling stops once at
    least ``top`` individuals are ranked (i.e. after the front containing the
    ``top``-th survivor completes). Every individual beyond the cutoff gets
    the sentinel rank ``P - 1`` — larger than any peeled front's rank, so
    (rank asc, crowd desc) truncation to the top ``top`` never selects one.
    Ranks ≤ the cutoff front are identical to the full sort; the while_loop
    simply runs fewer trips (elitist μ+λ survival only needs ranks up to the
    front holding the P-th survivor, typically a small fraction of the 2P
    combined population).
    """
    P = F.shape[0]
    if dom is None:
        dom = dominance_matrix(F)  # dom[i, j]: i dominates j
    quota = P if top is None else min(int(top), P)

    def cond(state):
        rank, _, k = state
        n_ranked = jnp.sum(rank >= 0)
        return jnp.any(rank < 0) & (k < P) & (n_ranked < quota)

    def body(state):
        rank, dom_cnt, k = state
        unranked = rank < 0
        # current front: unranked with zero unranked dominators
        front = unranked & (dom_cnt == 0)
        rank = jnp.where(front, k, rank)
        # remove this front's dominance contributions
        dec = jnp.sum(dom & front[:, None], axis=0)
        dom_cnt = jnp.where(unranked, dom_cnt - dec, dom_cnt)
        # peeled individuals get a sentinel count so they never re-enter
        dom_cnt = jnp.where(front, jnp.iinfo(jnp.int32).max, dom_cnt)
        return rank, dom_cnt, k + 1

    rank0 = jnp.full((P,), -1, dtype=jnp.int32)
    cnt0 = jnp.sum(dom, axis=0).astype(jnp.int32)
    rank, _, _ = jax.lax.while_loop(cond, body, (rank0, cnt0, jnp.int32(0)))
    # Beyond-cutoff individuals (and, as a safety net, anything unranked,
    # which cannot happen mathematically with top=None) -> last rank.
    return jnp.where(rank < 0, P - 1, rank).astype(jnp.int32)


def crowding_distance(F: jax.Array, rank: jax.Array) -> jax.Array:
    """Crowding distance per individual, computed within its own front.

    Boundary solutions of each front get +inf. Distances are normalized per
    objective by the front's objective range (NSGA-II, Deb et al. 2002).
    """
    P, M = F.shape
    INF = jnp.inf

    def per_objective(f_m):
        # Sort whole population by (rank, objective) so that individuals of
        # the same front are contiguous and ordered by this objective.
        order = jnp.lexsort((f_m, rank))  # primary: rank, secondary: f_m
        f_sorted = f_m[order]
        r_sorted = rank[order]
        # neighbors within the same front
        prev_same = jnp.concatenate([jnp.array([False]), r_sorted[1:] == r_sorted[:-1]])
        next_same = jnp.concatenate([r_sorted[:-1] == r_sorted[1:], jnp.array([False])])
        f_prev = jnp.concatenate([f_sorted[:1], f_sorted[:-1]])
        f_next = jnp.concatenate([f_sorted[1:], f_sorted[-1:]])
        gap = jnp.where(prev_same & next_same, f_next - f_prev, INF)
        # normalize by front range: front min/max via segment ops
        # boundary (first/last of front in this objective) -> INF
        # compute range per front using segment min/max over rank ids
        fmin = jax.ops.segment_min(f_sorted, r_sorted, num_segments=P)
        fmax = jax.ops.segment_max(f_sorted, r_sorted, num_segments=P)
        rng = (fmax - fmin)[r_sorted]
        rng = jnp.where(rng <= 0, 1.0, rng)
        contrib = jnp.where(jnp.isinf(gap), INF, gap / rng)
        # scatter back to original order
        out = jnp.zeros_like(f_m).at[order].set(contrib)
        return out

    dists = jax.vmap(per_objective, in_axes=1, out_axes=1)(F.astype(jnp.float32))
    return jnp.sum(dists, axis=1)  # inf + finite = inf, as desired


def hypervolume_2d(F: jax.Array, ref: jax.Array) -> jax.Array:
    """Exact hypervolume for M=2 minimization problems w.r.t. ``ref``.

    Dominated or out-of-reference points contribute zero.
    """
    # Keep only points that are <= ref in both objectives; others clamp to ref
    Fc = jnp.minimum(F, ref[None, :])
    # sort by first objective ascending
    order = jnp.argsort(Fc[:, 0])
    x = Fc[order, 0]
    y = Fc[order, 1]
    # running minimum of y defines the staircase
    y_min = jax.lax.associative_scan(jnp.minimum, y)
    # width of each step: next x (or ref) minus current x, but only where this
    # point improves the staircase (y < prior running min)
    y_prev = jnp.concatenate([ref[1:2], y_min[:-1]])
    height = jnp.maximum(y_prev - jnp.minimum(y, y_prev), 0.0)
    x_next = jnp.concatenate([x[1:], ref[0:1]])
    width = jnp.maximum(x_next - x, 0.0)
    # staircase area: sum over points of width * (ref1 - staircase height)...
    # simpler: area = sum_i width_i * (ref[1] - y_min_i)
    area = jnp.sum(width * jnp.maximum(ref[1] - y_min, 0.0))
    del height
    return area


@functools.partial(jax.jit, static_argnames=("n_samples",))
def hypervolume_mc(F: jax.Array, ref: jax.Array, ideal: jax.Array, key: jax.Array,
                   n_samples: int = 8192) -> jax.Array:
    """Monte-Carlo hypervolume estimate for arbitrary M (minimization).

    Samples uniformly in the [ideal, ref] box and counts the dominated
    fraction. Used for convergence tracking of the 3-objective (RQ, C, RT)
    router optimization, where exact HV is O(P log P) per slice but MC is
    simpler and cheap under jit.
    """
    M = F.shape[1]
    u = jax.random.uniform(key, (n_samples, M))
    pts = ideal[None, :] + u * (ref - ideal)[None, :]
    # point p is dominated by front member f if f <= p in all objectives
    dominated = jnp.any(jnp.all(F[None, :, :] <= pts[:, None, :], axis=-1), axis=1)
    box = jnp.prod(ref - ideal)
    return jnp.mean(dominated.astype(jnp.float32)) * box
